"""Benchmark: framework train/decode step cost on reduced configs (CPU),
plus the ZeRO-vs-allreduce train-step A/B behind ``BENCH_train.json``.

Ties the paper's "abstraction costs nothing" claim to the LM framework: the
foopar-TP (algebra) matmul path vs the pjit path on the same reduced model.
The A/B compares the layout the planner picks (grads reduce-scattered over
the 8-way fsdp group, AdamW on the local shard, params gathered per layer)
against the pre-ZeRO baseline (params/optimizer replicated, grads
all-reduced, every device runs the full redundant update) on an identical
step — same loss, different layout.  CSV: name,us_per_call,derived.

REPRO_LM_SMOKE=1 shrinks everything for the CI smoke step.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro import configs
from repro.config import ParallelConfig, ShapeConfig, TrainConfig
from repro.core import costmodel
from repro.launch.mesh import make_local_mesh
from repro.launch.train import reduced
from repro.parallel import steps as S
from repro.parallel.sharding import make_ctx
from repro.data import make_batch_iterator


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def zero_vs_allreduce(smoke: bool):
    """ZeRO-vs-allreduce A/B on the 8-way CPU mesh (see module docstring);
    the model column is ``costmodel.train_step_cost`` with the flops rate
    calibrated from a measured serial matmul — the *ordering* of the two
    strategies, not the hardware constants, is what the model must get
    right."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = reduced(configs.get("llama3.2-3b"))
    if not smoke:
        # param-heavy / token-light so the optimizer segment (what ZeRO
        # shards) is a visible slice of the step on the CPU sim
        cfg = cfg.replace(d_model=512, d_ff=1024, vocab=8192, head_dim=128)
    mesh = make_local_mesh()
    shards = mesh.shape["data"]
    shape = ShapeConfig("bench", "train", 8 if smoke else 16, shards)
    tcfg = TrainConfig(warmup_steps=1, z_loss=0.0)
    pc = cfg.param_counts()

    # calibration: flops rate from a serial matmul, byte rate from a big
    # elementwise op; the CPU sim's "interconnect" is host memory, so the
    # link class shares the measured byte rate
    n = 256
    A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
    B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
    t_mm, _ = timeit(jax.jit(jnp.matmul), A, B)
    flops_rate = 2.0 * n ** 3 / t_mm
    x = jnp.array(np.random.RandomState(2).randn(1 << 22), jnp.float32)
    t_ew, _ = timeit(jax.jit(lambda v: v * 1.0001 + 0.1), x)
    byte_rate = 2.0 * x.size * 4 / t_ew
    link = costmodel.LinkClass(t_s=1e-4, t_w=1.0 / byte_rate)

    variants = {
        "all_reduce": ParallelConfig(remat="none", fsdp_params=False,
                                     grad_dtype="float32"),
        "zero": ParallelConfig(remat="none", fsdp_params=True,
                               grad_dtype="float32",
                               grad_reduce="reduce_scatter_zero"),
    }
    times = {}
    for name, pcfg in variants.items():
        ctx = make_ctx(mesh, pcfg)
        state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
        sh = S.train_state_shardings(cfg, pcfg, ctx, state)
        bsh = {"tokens": NamedSharding(mesh, P(("data",), None))}
        step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, ctx),
                       in_shardings=(sh, bsh), out_shardings=(sh, None),
                       donate_argnums=(0,))
        batch = jax.device_put(next(make_batch_iterator(cfg, shape)), bsh)
        st = jax.device_put(state, sh)
        st, _ = step(st, batch)
        jax.block_until_ready(st)
        ts = []
        for _ in range(4 if smoke else 10):
            t0 = time.perf_counter()
            st, m = step(st, batch)
            jax.block_until_ready(st)
            ts.append(time.perf_counter() - t0)
        model = costmodel.train_step_cost(
            pc["active"], pc["total"],
            tokens=float(shape.global_batch) * shape.seq_len, chips=shards,
            tp=1, dp=shards,
            fsdp_shard=shards if pcfg.fsdp_params else 1,
            grad=pcfg.grad_reduce, batch_local=shape.global_batch // shards,
            seq=shape.seq_len, d_model=cfg.d_model, n_layers=cfg.n_layers,
            grad_bytes=4, param_bytes=4, remat="none", link=link,
            peak_flops=flops_rate, hbm_bw=byte_rate)
        times[name] = min(ts)
        print(f"train_{name},{min(ts)*1e6:.0f},"
              f"model_us={model['total_s']*1e6:.0f};shards={shards};"
              f"loss={float(m['loss']):.3f}")
    if not smoke:
        assert times["zero"] <= times["all_reduce"] * 1.05, \
            ("ZeRO layout must not lose to the replicated all-reduce step",
             times)


def main():
    smoke = bool(os.environ.get("REPRO_LM_SMOKE"))
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    tcfg = TrainConfig(warmup_steps=1, z_loss=0.0)
    shape = ShapeConfig("bench", "train", 128, 8)
    archs = ("llama3.2-3b",) if smoke else \
        ("llama3.2-3b", "mixtral-8x22b", "zamba2-1.2b", "xlstm-1.3b")
    for arch in archs:
        cfg = reduced(configs.get(arch))
        state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
        step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, None))
        batch = next(make_batch_iterator(cfg, shape))
        t, (state2, m) = timeit(step, state, batch)
        toks = shape.seq_len * shape.global_batch
        print(f"lmstep_{arch},{t*1e6:.0f},tok_per_s={toks/t:.0f};"
              f"loss={float(m['loss']):.3f}")
    zero_vs_allreduce(smoke)


if __name__ == "__main__":
    main()
