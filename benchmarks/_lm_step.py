"""Benchmark: framework train/decode step cost on reduced configs (CPU).

Ties the paper's "abstraction costs nothing" claim to the LM framework: the
foopar-TP (algebra) matmul path vs the pjit path on the same reduced model.
CSV: name,us_per_call,derived.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro import configs
from repro.config import ParallelConfig, ShapeConfig, TrainConfig
from repro.launch.train import reduced
from repro.parallel import steps as S
from repro.data import make_batch_iterator


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def main():
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    tcfg = TrainConfig(warmup_steps=1, z_loss=0.0)
    shape = ShapeConfig("bench", "train", 128, 8)
    for arch in ("llama3.2-3b", "mixtral-8x22b", "zamba2-1.2b", "xlstm-1.3b"):
        cfg = reduced(configs.get(arch))
        state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
        step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, None))
        batch = next(make_batch_iterator(cfg, shape))
        t, (state2, m) = timeit(step, state, batch)
        toks = shape.seq_len * shape.global_batch
        print(f"lmstep_{arch},{t*1e6:.0f},tok_per_s={toks/t:.0f};"
              f"loss={float(m['loss']):.3f}")


if __name__ == "__main__":
    main()
