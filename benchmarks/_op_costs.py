"""Benchmark: Table-1 group-operation costs (paper Table 1).

Measures each DSeq op on an 8-process CPU group and reports measured
microseconds next to the cost model's Θ-shape (scaled to the measured t_s,
t_w of this host).  CSV: name,us_per_call,derived.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import DSeq, spmd, make_grid_mesh
from repro.core import costmodel


def bench(fn, *args, iters=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    mesh = make_grid_mesh((8,), ("x",))
    m = 1 << 16  # elements per process
    x = jnp.arange(8.0 * m).reshape(8, m)

    ops = {
        "mapD": lambda xl: DSeq(xl, "x").mapD(lambda v: v * 2 + 1).local,
        "zipWithD": lambda xl: DSeq(xl, "x").zipWithD(DSeq(xl, "x"),
                                                      jnp.add).local,
        "reduceD_sum": lambda xl: DSeq(xl[0], "x").reduceD("sum")[None],
        "reduceD_tree": lambda xl: DSeq(xl[0], "x").reduceD(jnp.add)[None],
        "shiftD": lambda xl: DSeq(xl, "x").shiftD(1).local,
        "allGatherD": lambda xl: DSeq(xl[0], "x").allGatherD()[None],
        "applyD_bcast": lambda xl: DSeq(xl[0], "x").apply(3)[None],
        "allToAllD": lambda xl: DSeq(xl.reshape(8, -1), "x").allToAllD()
        .local.reshape(1, -1),
    }
    model = {
        "mapD": 0.0, "zipWithD": 0.0,
        "reduceD_sum": costmodel.t_reduce(m * 4, 8),
        "reduceD_tree": costmodel.t_reduce(m * 4, 8),
        "shiftD": costmodel.t_shift(m * 4, 8),
        "allGatherD": costmodel.t_all_gather(m * 4, 8),
        "applyD_bcast": costmodel.t_broadcast(m * 4, 8),
        "allToAllD": costmodel.t_all_to_all(m * 4 / 8, 8),
    }
    for name, body in ops.items():
        out_spec = P("x", None) if name in ("mapD", "zipWithD", "shiftD",
                                            "reduceD_sum", "reduceD_tree",
                                            "applyD_bcast", "allToAllD") \
            else P(None, None)
        in_spec = P("x", None)
        f = jax.jit(spmd(body, mesh, in_specs=in_spec, out_specs=out_spec))
        us = bench(f, x)
        print(f"table1_{name},{us:.1f},model_icis={model[name]*1e6:.2f}us")


if __name__ == "__main__":
    main()
