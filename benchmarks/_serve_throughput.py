"""Benchmark: continuous-batching scheduler vs naive sequential serving,
plus the paged-vs-end-aligned KV-cache A/B.

The same engine (``launch/scheduler.py``) serves an identical staggered
request stream twice — once with a single slot (the naive one-request-at-
a-time server) and once with a slot pool — so the A/B isolates exactly the
continuous-batching win.  Both runs are warmed first (JIT compile excluded)
and timed behind ``block_until_ready``.

The paged rows then run the block-pool engine (``serving/kvcache.py``,
chunked prefill admission): ``serve_paged`` serves the SAME short stream at
the SAME total cache memory as the end-aligned pool (the layout tax A/B);
``serve_paged_long`` serves a long-prompt mix whose big requests
(prompt + gen > max_len) the end-aligned engine must reject at submit —
asserted here — so that row measures capacity the rigid layout simply does
not have.  Model columns: ``costmodel.paged_decode_step_cost`` (page-table
gather term) next to ``decode_step_cost``.

Next to each measured tok/s the Table-1-style serving cost model prediction
is printed, calibrated the same way as _summa_vs_dns: the flops rate from a
measured serial matmul and the per-step dispatch floor from a measured warm
B=1 decode step, so the model's *batch-amortization* term — not the
hardware constants — is what is tested.  CSV: name,us_per_tok,derived.

REPRO_SERVE_SMOKE=1 shrinks everything for the CI smoke step.
"""
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro import configs
from repro.config import ParallelConfig
from repro.core import costmodel
from repro.launch.roofline import kv_bytes_per_seq
from repro.launch.scheduler import Scheduler, make_requests
from repro.launch.train import reduced
from repro.models import transformer as T
from repro.parallel import steps as S


def timeit(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def step_time(cfg, pcfg, params, batch, max_len, iters=20):
    """Warm per-step wall time of the fixed-shape batched decode step."""
    decode = jax.jit(S.make_decode_step(cfg, pcfg, None))
    tok = jnp.zeros((batch,), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    cache = T.init_cache(cfg, batch, max_len)
    jax.block_until_ready(decode(params, tok, cache, pos))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(decode(params, tok, cache, pos))
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    smoke = bool(os.environ.get("REPRO_SERVE_SMOKE"))
    n_req, prompt, gen = (3, 8, 4) if smoke else (8, 16, 16)
    slots, stagger = (2, 1) if smoke else (4, 2)

    cfg = reduced(configs.get("llama3.2-3b"))
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    max_len = prompt + gen + 1
    n_active = cfg.param_counts()["active"]
    kv = kv_bytes_per_seq(cfg, max_len)

    # calibration: flops rate from a serial matmul, dispatch floor from a
    # measured warm B=1 decode step (its roofline terms are negligible here)
    n = 256
    A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
    B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
    flops_rate = 2.0 * n**3 / timeit(jax.jit(jnp.matmul), A, B)
    t1 = step_time(cfg, pcfg, params, 1, max_len)
    base = costmodel.decode_step_cost(n_active, 1, kv, peak_flops=flops_rate)
    overhead = max(t1 - base["total_s"], 0.0)

    results = {}
    for name, n_slots in (("sequential", 1), ("batched", slots)):
        sched = Scheduler(cfg, pcfg, params, slots=n_slots, max_len=max_len)
        sched.run(make_requests(2, prompt, 2, cfg.vocab))      # warmup/compile
        sched.reset()
        out = sched.run(make_requests(n_req, prompt, gen, cfg.vocab,
                                      stagger=stagger))
        assert len(out["completions"]) == n_req, out
        model = costmodel.decode_step_cost(n_active, n_slots, kv,
                                           peak_flops=flops_rate,
                                           overhead_s=overhead)
        results[name] = out
        print(f"serve_{name},{out['wall_s'] / out['generated'] * 1e6:.0f},"
              f"tok_s={out['tok_s']:.1f};model_tok_s={model['tok_s']:.1f};"
              f"slots={n_slots};requests={n_req}")
    assert results["batched"]["tok_s"] > results["sequential"]["tok_s"], \
        ("continuous batching must beat sequential serving", results)

    # ---- paged-vs-end-aligned A/B -------------------------------------
    # same slots and the same total cache memory as the end-aligned pool
    # (pool_blocks * block == slots * max_len tokens)
    block, chunk = (4, 4) if smoke else (8, 8)
    pool_blocks = slots * max_len // block
    kv_tok = kv_bytes_per_seq(cfg, 1)

    def paged_row(name, reqs, max_len_paged, expect):
        sched = Scheduler(cfg, pcfg, params, slots=slots,
                          max_len=max_len_paged, paged=True, block=block,
                          chunk=chunk, pool_blocks=pool_blocks)
        sched.run(make_requests(2, prompt, 2, cfg.vocab))      # warmup/compile
        sched.reset()
        out = sched.run(reqs)
        assert len(out["completions"]) == expect, out
        # per-row KV traffic: the long-prompt mix streams ~2x the cache of
        # the short mix, so the model column must be computed per scenario
        model = costmodel.paged_decode_step_cost(
            n_active, slots, kv_bytes_per_seq(cfg, max_len_paged),
            block=block, kv_token_bytes=kv_tok,
            peak_flops=flops_rate, overhead_s=overhead)
        rep = out["pool"]
        print(f"serve_{name},{out['wall_s'] / out['generated'] * 1e6:.0f},"
              f"tok_s={out['tok_s']:.1f};model_tok_s={model['tok_s']:.1f};"
              f"slots={slots};block={block};chunk={chunk};"
              f"peak_occ={rep['peak_occupancy']:.2f};"
              f"frag={rep['frag_at_peak']:.2f}")
        return out

    paged_row("paged", make_requests(n_req, prompt, gen, cfg.vocab,
                                     stagger=stagger), max_len, n_req)

    # long-prompt mix: the big requests exceed the per-slot row, so the
    # end-aligned engine MUST reject them at submit — the paged engine
    # serves the whole mix out of the same pool memory
    long_prompt = max_len + gen                  # prompt alone > max_len
    n_long = max(2, n_req // 2)
    long_reqs = make_requests(n_long, long_prompt, gen, cfg.vocab,
                              stagger=stagger, seed=5)
    ea = Scheduler(cfg, pcfg, params, slots=slots, max_len=max_len)
    for r in long_reqs:
        try:
            ea.submit(r)
            raise AssertionError(f"end-aligned accepted over-long {r.rid}")
        except ValueError:
            pass
    paged_row("paged_long", long_reqs, long_prompt + gen, n_long)


if __name__ == "__main__":
    main()
