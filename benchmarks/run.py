"""Benchmark harness — one benchmark per paper table/figure.

  Table 1  (op costs)          -> _op_costs.py
  Fig. 5   (matmul efficiency) -> _matmul_efficiency.py
  §5       (Floyd-Warshall)    -> _floyd_warshall.py
  §4.2/4.3 (isoefficiency)     -> _isoefficiency.py (analytical, in-process)
  framework step cost          -> _lm_step.py

Each multi-device benchmark runs in a subprocess (needs its own
XLA_FLAGS=--xla_force_host_platform_device_count before jax init).
Prints ``name,us_per_call,derived`` CSV lines.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SUBPROCESS_BENCHES = ["_op_costs.py", "_matmul_efficiency.py",
                      "_summa_vs_dns.py", "_floyd_warshall.py", "_lm_step.py"]


def _isoefficiency() -> None:
    """Paper §4.2.1/§4.3: evaluate the isoefficiency functions and verify the
    scalability ordering generic ≫ grid ≈ DNS (analysis, no devices)."""
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.core import costmodel as cm
    for p in (64, 512, 4096):
        w_gen = cm.isoefficiency_matmul_generic(p)
        w_grid = cm.isoefficiency_matmul_grid(p)
        w_summa = cm.isoefficiency_matmul_summa(p)
        w_fw = cm.isoefficiency_floyd_warshall(p)
        print(f"iso_generic_p{p},0,W={w_gen:.3e}")
        print(f"iso_grid_p{p},0,W={w_grid:.3e};ratio_vs_generic={w_gen/w_grid:.1f}")
        print(f"iso_summa_p{p},0,W={w_summa:.3e};"
              f"cannon={cm.isoefficiency_matmul_cannon(p):.3e}")
        print(f"iso_fw_p{p},0,W={w_fw:.3e}")
    # predicted DNS time at TPU scale (ties Table 1 to the roofline)
    for n, q in ((40000, 8),):
        pred = cm.dns_matmul_cost(n, q, bytes_per_elt=2)
        print(f"iso_dns_pred_n{n}_p{q**3},{pred['total_s']*1e6:.0f},"
              f"eff={pred['serial_s']/(q**3*pred['total_s']):.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    _isoefficiency()
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for bench in SUBPROCESS_BENCHES:
        r = subprocess.run([sys.executable, os.path.join(HERE, bench)],
                           capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            print(f"{bench},ERROR,{r.stderr[-400:]!r}", file=sys.stderr)
            raise SystemExit(f"benchmark {bench} failed")
        for line in r.stdout.splitlines():
            if "," in line and not line.startswith(("W", "I", "/")):
                print(line)


if __name__ == "__main__":
    main()
