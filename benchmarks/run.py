"""Benchmark harness — one benchmark per paper table/figure.

  Table 1  (op costs)          -> _op_costs.py
  Fig. 5   (matmul efficiency) -> _matmul_efficiency.py
  §5       (Floyd-Warshall)    -> _floyd_warshall.py
  §4.2/4.3 (isoefficiency)     -> _isoefficiency.py (analytical, in-process)
  framework step cost          -> _lm_step.py

Each multi-device benchmark runs in a subprocess (needs its own
XLA_FLAGS=--xla_force_host_platform_device_count before jax init).
Prints ``name,us_per_call,derived`` CSV lines.
"""
import json
import os
import re
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
MATMUL_JSON = os.path.join(HERE, "..", "BENCH_matmul.json")
SERVE_JSON = os.path.join(HERE, "..", "BENCH_serve.json")
SUBPROCESS_BENCHES = ["_op_costs.py", "_matmul_efficiency.py",
                      "_summa_vs_dns.py", "_floyd_warshall.py", "_lm_step.py",
                      "_serve_throughput.py"]


def _isoefficiency() -> None:
    """Paper §4.2.1/§4.3: evaluate the isoefficiency functions and verify the
    scalability ordering generic ≫ grid ≈ DNS (analysis, no devices)."""
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.core import costmodel as cm
    for p in (64, 512, 4096):
        w_gen = cm.isoefficiency_matmul_generic(p)
        w_grid = cm.isoefficiency_matmul_grid(p)
        w_summa = cm.isoefficiency_matmul_summa(p)
        w_fw = cm.isoefficiency_floyd_warshall(p)
        print(f"iso_generic_p{p},0,W={w_gen:.3e}")
        print(f"iso_grid_p{p},0,W={w_grid:.3e};ratio_vs_generic={w_gen/w_grid:.1f}")
        print(f"iso_summa_p{p},0,W={w_summa:.3e};"
              f"cannon={cm.isoefficiency_matmul_cannon(p):.3e}")
        print(f"iso_fw_p{p},0,W={w_fw:.3e}")
    # predicted DNS time at TPU scale (ties Table 1 to the roofline)
    for n, q in ((40000, 8),):
        pred = cm.dns_matmul_cost(n, q, bytes_per_elt=2)
        print(f"iso_dns_pred_n{n}_p{q**3},{pred['total_s']*1e6:.0f},"
              f"eff={pred['serial_s']/(q**3*pred['total_s']):.3f}")


def _write_matmul_json(lines: list) -> None:
    """Machine-readable per-PR perf trajectory: variant -> measured
    us_per_call and model-predicted cost at the largest benchmarked size
    (BENCH_matmul.json at the repo root, diffable across PRs)."""
    pat = re.compile(r"^summa_vs_dns_(\w+?)_n(\d+),(\d+),model_us=(\d+)")
    table = {}
    for line in lines:
        m = pat.match(line)
        if not m:
            continue
        variant, n, us, model_us = m.group(1), *map(int, m.group(2, 3, 4))
        if variant not in table or n >= table[variant]["n"]:
            table[variant] = {"n": n, "us_per_call": us, "model_us": model_us}
    if table:
        with open(MATMUL_JSON, "w") as f:
            json.dump(table, f, indent=2, sort_keys=True)
            f.write("\n")


def _write_serve_json(lines: list) -> None:
    """Machine-readable serving A/B (BENCH_serve.json at the repo root,
    diffable across PRs like BENCH_matmul.json): mode -> measured us/tok,
    tok/s and the decode_step_cost-predicted tok/s."""
    pat = re.compile(r"^serve_(\w+),(\d+),tok_s=([\d.]+);model_tok_s=([\d.]+)"
                     r";slots=(\d+)")
    table = {}
    for line in lines:
        m = pat.match(line)
        if not m:
            continue
        table[m.group(1)] = {"us_per_tok": int(m.group(2)),
                             "tok_s": float(m.group(3)),
                             "model_tok_s": float(m.group(4)),
                             "slots": int(m.group(5))}
    if table:
        with open(SERVE_JSON, "w") as f:
            json.dump(table, f, indent=2, sort_keys=True)
            f.write("\n")


def main() -> None:
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
        assert only in SUBPROCESS_BENCHES, (only, SUBPROCESS_BENCHES)
    print("name,us_per_call,derived")
    if only is None:
        _isoefficiency()
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    matmul_lines = []
    serve_lines = []
    for bench in SUBPROCESS_BENCHES if only is None else [only]:
        r = subprocess.run([sys.executable, os.path.join(HERE, bench)],
                           capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            print(f"{bench},ERROR,{r.stderr[-400:]!r}", file=sys.stderr)
            raise SystemExit(f"benchmark {bench} failed")
        for line in r.stdout.splitlines():
            if "," in line and not line.startswith(("W", "I", "/")):
                print(line)
                if line.startswith("summa_vs_dns_"):
                    matmul_lines.append(line)
                elif line.startswith("serve_"):
                    serve_lines.append(line)
    _write_matmul_json(matmul_lines)
    _write_serve_json(serve_lines)


if __name__ == "__main__":
    main()
