"""Benchmark harness — one benchmark per paper table/figure.

  Table 1  (op costs)          -> _op_costs.py
  Fig. 5   (matmul efficiency) -> _matmul_efficiency.py
  §5       (Floyd-Warshall)    -> _floyd_warshall.py
  §4.2/4.3 (isoefficiency)     -> _isoefficiency.py (analytical, in-process)
  framework step cost          -> _lm_step.py (+ ZeRO-vs-allreduce A/B
                                  -> BENCH_train.json; alias: --only train)

Each multi-device benchmark runs in a subprocess (needs its own
XLA_FLAGS=--xla_force_host_platform_device_count before jax init).
Prints ``name,us_per_call,derived`` CSV lines.
"""
import json
import os
import re
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
MATMUL_JSON = os.path.join(HERE, "..", "BENCH_matmul.json")
SERVE_JSON = os.path.join(HERE, "..", "BENCH_serve.json")
TRAIN_JSON = os.path.join(HERE, "..", "BENCH_train.json")
SUBPROCESS_BENCHES = ["_op_costs.py", "_matmul_efficiency.py",
                      "_summa_vs_dns.py", "_floyd_warshall.py", "_lm_step.py",
                      "_serve_throughput.py"]
ALIASES = {"train": "_lm_step.py", "serve": "_serve_throughput.py",
           "matmul": "_summa_vs_dns.py"}


def _isoefficiency() -> None:
    """Paper §4.2.1/§4.3: evaluate the isoefficiency functions and verify the
    scalability ordering generic ≫ grid ≈ DNS (analysis, no devices)."""
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.core import costmodel as cm
    for p in (64, 512, 4096):
        w_gen = cm.isoefficiency_matmul_generic(p)
        w_grid = cm.isoefficiency_matmul_grid(p)
        w_summa = cm.isoefficiency_matmul_summa(p)
        w_fw = cm.isoefficiency_floyd_warshall(p)
        print(f"iso_generic_p{p},0,W={w_gen:.3e}")
        print(f"iso_grid_p{p},0,W={w_grid:.3e};ratio_vs_generic={w_gen/w_grid:.1f}")
        print(f"iso_summa_p{p},0,W={w_summa:.3e};"
              f"cannon={cm.isoefficiency_matmul_cannon(p):.3e}")
        print(f"iso_fw_p{p},0,W={w_fw:.3e}")
    # predicted DNS time at TPU scale (ties Table 1 to the roofline)
    for n, q in ((40000, 8),):
        pred = cm.dns_matmul_cost(n, q, bytes_per_elt=2)
        print(f"iso_dns_pred_n{n}_p{q**3},{pred['total_s']*1e6:.0f},"
              f"eff={pred['serial_s']/(q**3*pred['total_s']):.3f}")


# Machine-readable per-PR perf trajectories (BENCH_*.json at the repo root,
# diffable across PRs): one spec per trajectory — CSV-line regex, field
# names/types for the named groups after the key, and the output path.
# ``keep`` resolves duplicate keys (matmul keeps the largest size n).
BENCH_JSON = {
    "summa_vs_dns_": {
        "path": MATMUL_JSON,
        "pattern": r"^summa_vs_dns_(\w+?)_n(\d+),(\d+),model_us=(\d+)",
        "fields": (("n", int), ("us_per_call", int), ("model_us", int)),
        "keep": lambda old, new: new["n"] >= old["n"],
    },
    "serve_": {
        "path": SERVE_JSON,
        # paged rows carry extra block/chunk/occupancy fields (optional
        # trailing group; absent fields are skipped in the record)
        "pattern": r"^serve_(\w+),(\d+),tok_s=([\d.]+);model_tok_s=([\d.]+)"
                   r";slots=(\d+)(?:;block=(\d+);chunk=(\d+)"
                   r";peak_occ=([\d.]+);frag=([\d.]+))?",
        "fields": (("us_per_tok", int), ("tok_s", float),
                   ("model_tok_s", float), ("slots", int), ("block", int),
                   ("chunk", int), ("peak_occ", float), ("frag", float)),
    },
    "train_": {
        "path": TRAIN_JSON,
        "pattern": r"^train_(\w+),(\d+),model_us=(\d+);shards=(\d+)",
        "fields": (("us_per_call", int), ("model_us", int), ("shards", int)),
    },
}


def _write_bench_json(spec: dict, lines: list) -> None:
    pat = re.compile(spec["pattern"])
    table = {}
    for line in lines:
        m = pat.match(line)
        if not m:
            continue
        key = m.group(1)
        rec = {name: typ(val) for (name, typ), val
               in zip(spec["fields"], m.groups()[1:]) if val is not None}
        if key not in table or spec.get("keep", lambda o, n: True)(table[key], rec):
            table[key] = rec
    if table:
        with open(spec["path"], "w") as f:
            json.dump(table, f, indent=2, sort_keys=True)
            f.write("\n")


def main() -> None:
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
        only = ALIASES.get(only, only)
        assert only in SUBPROCESS_BENCHES, (only, SUBPROCESS_BENCHES)
    print("name,us_per_call,derived")
    if only is None:
        _isoefficiency()
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    bench_lines = {prefix: [] for prefix in BENCH_JSON}
    for bench in SUBPROCESS_BENCHES if only is None else [only]:
        r = subprocess.run([sys.executable, os.path.join(HERE, bench)],
                           capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            print(f"{bench},ERROR,{r.stderr[-400:]!r}", file=sys.stderr)
            raise SystemExit(f"benchmark {bench} failed")
        for line in r.stdout.splitlines():
            if "," in line and not line.startswith(("W", "I", "/")):
                print(line)
                for prefix in BENCH_JSON:
                    if line.startswith(prefix):
                        bench_lines[prefix].append(line)
    for prefix, spec in BENCH_JSON.items():
        _write_bench_json(spec, bench_lines[prefix])


if __name__ == "__main__":
    main()
