"""Benchmark: parallel matmul efficiency (paper Fig. 5, CPU analogue).

The paper measures DNS-matmul efficiency vs single-core peak on Carver
(512 cores).  Here: Grid3D DNS on a 2×2×2 8-device host mesh vs the
single-device matmul, E = T_serial / (p · T_p).  Also the generic
Algorithm-1 variant to expose its Θ(p^{5/3}) overhead experimentally.
CSV: name,us_per_call,derived.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import dns_matmul, generic_matmul, make_grid_mesh


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))
    mesh1 = make_grid_mesh((8,), ("z",))
    for n in (256, 512, 1024):
        A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
        B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
        t_serial = timeit(jax.jit(jnp.matmul), A, B)
        t_dns = timeit(jax.jit(lambda a, b: dns_matmul(a, b, mesh3)), A, B)
        t_gen = timeit(jax.jit(lambda a, b: generic_matmul(a, b, mesh1, "z")),
                       A, B)
        e_dns = t_serial / (8 * t_dns)
        e_gen = t_serial / (8 * t_gen)
        gflops = 2 * n ** 3 / t_dns / 1e9
        print(f"fig5_dns_n{n},{t_dns*1e6:.0f},eff={e_dns:.3f};gflops={gflops:.1f}")
        print(f"fig5_generic_n{n},{t_gen*1e6:.0f},eff={e_gen:.3f}")


if __name__ == "__main__":
    main()
