"""Benchmark: the five-variant parallel-matmul scenario space (paper §4.3 +
the overlapped/replicated tier).

8 fake CPU devices, each algorithm on the projection of the same 8 chips
that exposes its communication structure: DNS and Cannon-2.5D on the 2×2×2
cube, Cannon on the 2×4 torus (nearest-neighbour 2D traffic), and the
SUMMA tree-vs-ring A/B pair on the 1×8 projection — there the per-panel
broadcast spans all 8 chips, which is the regime the pipelined variant's
ring transfers target (on small broadcast groups tree and ring coincide
and the comparison measures only backend noise).  For each algorithm the
measured wall time is printed next to the Table-1 cost-model prediction
(with the serial matmul as the peak_flops calibration, so the model's
communication terms — not the hardware constants — are what is tested).
CSV: name,us_per_call,derived.

Sizes default to 256,512,1024; override with REPRO_BENCH_SIZES=128 (the CI
smoke step) or a comma list.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import (cannon_matmul, cannon_matmul_25d, costmodel,
                        dns_matmul, make_grid_mesh, summa_matmul,
                        summa_matmul_pipelined)


def timeit(fn, *args, iters=10):
    """Best-of-iters: the minimum is the least scheduler-noise-contaminated
    estimate on the oversubscribed 8-threads-as-8-devices CPU host."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))
    mesh2 = make_grid_mesh((2, 4), ("x", "y"))
    mesh1x8 = make_grid_mesh((1, 8), ("x", "y"))
    sizes = tuple(int(s) for s in
                  os.environ.get("REPRO_BENCH_SIZES", "256,512,1024").split(","))
    for n in sizes:
        A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
        B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
        t_serial = timeit(jax.jit(jnp.matmul), A, B)
        # calibrate the model's flops rate from the measured serial time so
        # the prediction isolates the communication structure
        flops_rate = 2.0 * n**3 / t_serial
        runs = {
            "dns": (timeit(jax.jit(lambda a, b: dns_matmul(a, b, mesh3)), A, B),
                    costmodel.dns_matmul_cost(n, 2, peak_flops=flops_rate)),
            "summa": (timeit(jax.jit(lambda a, b: summa_matmul(a, b, mesh1x8)),
                             A, B),
                      costmodel.summa_matmul_cost(n, 1, 8, peak_flops=flops_rate)),
            "summa_pipelined": (
                timeit(jax.jit(lambda a, b: summa_matmul_pipelined(a, b, mesh1x8)),
                       A, B),
                costmodel.summa_pipelined_cost(n, 1, 8, peak_flops=flops_rate)),
            "summa_2x4": (
                timeit(jax.jit(lambda a, b: summa_matmul(a, b, mesh2)), A, B),
                costmodel.summa_matmul_cost(n, 2, 4, peak_flops=flops_rate)),
            "cannon": (timeit(jax.jit(lambda a, b: cannon_matmul(a, b, mesh2)), A, B),
                       costmodel.cannon_matmul_cost(n, 2, 4, peak_flops=flops_rate)),
            "cannon_25d": (
                timeit(jax.jit(lambda a, b: cannon_matmul_25d(a, b, mesh3)), A, B),
                costmodel.cannon_25d_cost(n, 2, 2, peak_flops=flops_rate)),
        }
        for name, (t_meas, pred) in runs.items():
            eff = t_serial / (8 * t_meas)
            print(f"summa_vs_dns_{name}_n{n},{t_meas*1e6:.0f},"
                  f"model_us={pred['total_s']*1e6:.0f};eff={eff:.3f}")


if __name__ == "__main__":
    main()
