"""Benchmark: 2D SUMMA / Cannon vs 3D DNS matmul (the §4.3 scenario space).

8 fake CPU devices, three grid projections of the same 8 chips:
DNS on 2×2×2, SUMMA and Cannon on a 2×4 grid.  For each algorithm the
measured wall time is printed next to the Table-1 cost-model prediction
(with the serial matmul as the peak_flops calibration, so the model's
communication terms — not the hardware constants — are what is tested).
CSV: name,us_per_call,derived.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import (cannon_matmul, costmodel, dns_matmul, make_grid_mesh,
                        summa_matmul)


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))
    mesh2 = make_grid_mesh((2, 4), ("x", "y"))
    for n in (256, 512, 1024):
        A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
        B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
        t_serial = timeit(jax.jit(jnp.matmul), A, B)
        # calibrate the model's flops rate from the measured serial time so
        # the prediction isolates the communication structure
        flops_rate = 2.0 * n**3 / t_serial
        runs = {
            "dns": (timeit(jax.jit(lambda a, b: dns_matmul(a, b, mesh3)), A, B),
                    costmodel.dns_matmul_cost(n, 2, peak_flops=flops_rate)),
            "summa": (timeit(jax.jit(lambda a, b: summa_matmul(a, b, mesh2)), A, B),
                      costmodel.summa_matmul_cost(n, 2, 4, peak_flops=flops_rate)),
            "cannon": (timeit(jax.jit(lambda a, b: cannon_matmul(a, b, mesh2)), A, B),
                       costmodel.cannon_matmul_cost(n, 2, 4, peak_flops=flops_rate)),
        }
        for name, (t_meas, pred) in runs.items():
            eff = t_serial / (8 * t_meas)
            print(f"summa_vs_dns_{name}_n{n},{t_meas*1e6:.0f},"
                  f"model_us={pred['total_s']*1e6:.0f};eff={eff:.3f}")


if __name__ == "__main__":
    main()
