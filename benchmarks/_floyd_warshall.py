"""Benchmark: parallel Floyd-Warshall (paper §5) — faithful Algorithm 3 vs
the blocked beyond-paper variant, on a 2×2 grid.  CSV: name,us_per_call,derived."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import floyd_warshall, blocked_floyd_warshall, make_grid_mesh


def timeit(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    mesh = make_grid_mesh((2, 2), ("x", "y"))
    for n in (128, 256):
        rng = np.random.RandomState(0)
        W = rng.rand(n, n).astype(np.float32) * 10
        W[np.diag_indices(n)] = 0
        D = jnp.array(W)
        t_faithful = timeit(jax.jit(lambda d: floyd_warshall(d, mesh)), D)
        t_blocked = timeit(jax.jit(lambda d: blocked_floyd_warshall(d, mesh)), D)
        print(f"fw_faithful_n{n},{t_faithful*1e6:.0f},alg3")
        print(f"fw_blocked_n{n},{t_blocked*1e6:.0f},speedup={t_faithful/t_blocked:.2f}x")


if __name__ == "__main__":
    main()
