"""Paged KV-cache serving subsystem: host-side block-pool allocator.

The device-side pieces live next to their peers: the paged arena init in
``models.transformer.init_paged_cache``, the page-view attention in
``models.layers``, the Pallas decode kernel in ``kernels.paged_attention``,
and the chunked-prefill scheduler integration in ``launch.scheduler``.
"""
from .kvcache import BlockPool, PoolExhausted

__all__ = ["BlockPool", "PoolExhausted"]
