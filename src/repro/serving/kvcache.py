"""Paged KV-cache block-pool allocator (the serving subsystem's data layout).

The FooPar move applied to serving memory: the monolithic end-aligned cache
row (``prompt + gen <= max_len`` per slot) is replaced by a managed
distributed collection of fixed-size KV *pages*.  A request's logical token
sequence is a chain of pages named by its *block table*, so its length is
bounded by pool capacity, not by any per-slot rectangle — the layout that
makes ``prompt + gen`` longer than an end-aligned slot servable at all.

Split of responsibilities (mirrors the slot engine's host/device split):

  * ``BlockPool`` (here) is pure host-side accounting: the free list, the
    per-request page chains, admission *reservations*, and the occupancy /
    fragmentation report.  It never touches device memory, so the scheduler
    can keep donating the device arena through its jitted steps.
  * The device arena — one ``(n_periods, n_blocks, block, kv_heads, hd)``
    K and V pair per attention position in the block pattern — is built by
    ``models.transformer.init_paged_cache`` and threaded through the jitted
    decode / chunked-prefill steps exactly like the end-aligned cache.

Allocation protocol (all methods O(pages touched)):

  * ``admit(rid, total_tokens)`` — called once at admission; *reserves*
    ``blocks_needed(total_tokens)`` blocks so mid-flight growth can never
    fail (no preemption logic needed).  Admission control: the scheduler
    admits only while ``can_admit`` holds.
  * ``ensure(rid, tokens)`` — alloc-on-write: grows the request's page chain
    to cover ``tokens`` logical tokens (one call before every decode tick
    and prefill chunk); draws from the free list, never exceeds the
    reservation.
  * ``free(rid)`` — eviction: the whole chain returns to the free list and
    the reservation is released.

The hypothesis property test (tests/test_paged.py) drives random staggered
admit/ensure/free interleavings against the invariants: live chains are
pairwise disjoint, free + live always partitions the pool, and reservations
never oversubscribe it.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised when an allocation would exceed the pool (a scheduler bug:
    admission reserves worst-case blocks, so ``ensure`` can never hit it)."""


class BlockPool:
    """Fixed pool of ``n_blocks`` KV pages of ``block`` tokens each."""

    def __init__(self, n_blocks: int, block: int):
        if n_blocks < 1 or block < 1:
            raise ValueError(f"need n_blocks >= 1 and block >= 1, got "
                             f"{n_blocks}/{block}")
        self.n_blocks, self.block = n_blocks, block
        self.reset()

    def reset(self) -> None:
        # pop() from the tail -> blocks hand out in ascending order (stable
        # layouts for tests; not a correctness requirement)
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._pages: Dict[int, List[int]] = {}      # rid -> page chain
        self._tokens: Dict[int, int] = {}           # rid -> logical length
        self._reserved: Dict[int, int] = {}         # rid -> reserved blocks
        self.peak_live = 0
        self.frag_at_peak = 0.0

    # -- capacity arithmetic -------------------------------------------------
    def blocks_needed(self, tokens: int) -> int:
        return -(-tokens // self.block)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    def can_admit(self, total_tokens: int) -> bool:
        """True iff a request of ``total_tokens`` can be admitted *now*:
        its worst-case block count fits next to the existing reservations
        (reservation-based admission — ``ensure`` can then never fail)."""
        return (self.blocks_needed(total_tokens)
                <= self.n_blocks - self.reserved_blocks)

    # -- lifecycle -----------------------------------------------------------
    def admit(self, rid: int, total_tokens: int) -> None:
        if rid in self._reserved:
            raise ValueError(f"request {rid} is already admitted")
        need = self.blocks_needed(total_tokens)
        if need > self.n_blocks - self.reserved_blocks:
            raise PoolExhausted(
                f"request {rid} needs {need} blocks but only "
                f"{self.n_blocks - self.reserved_blocks} of {self.n_blocks} "
                f"are unreserved")
        self._reserved[rid] = need
        self._pages[rid] = []
        self._tokens[rid] = 0

    def ensure(self, rid: int, tokens: int) -> List[int]:
        """Grow ``rid``'s chain to cover ``tokens`` logical tokens
        (alloc-on-write); returns the (possibly grown) page chain."""
        need = self.blocks_needed(tokens)
        chain = self._pages[rid]
        if need > self._reserved[rid]:
            raise PoolExhausted(
                f"request {rid}: {tokens} tokens need {need} blocks, "
                f"reservation is {self._reserved[rid]}")
        while len(chain) < need:
            chain.append(self._free.pop())
        self._tokens[rid] = max(self._tokens[rid], tokens)
        live = self.live_blocks
        if live >= self.peak_live:
            # snapshot internal fragmentation at the high-water mark (the
            # end-of-run report would otherwise read an empty pool)
            self.peak_live = live
            used = sum(self._tokens.values())
            self.frag_at_peak = 1.0 - used / (live * self.block) if live else 0.0
        return chain

    def free(self, rid: int) -> None:
        """Eviction: the chain returns to the free list (reverse order keeps
        the hand-out ascending), the reservation is released."""
        self._free.extend(reversed(self._pages.pop(rid)))
        del self._tokens[rid]
        del self._reserved[rid]

    def table(self, rid: int, width: int) -> np.ndarray:
        """The request's block table as a fixed-width int32 row: the page
        chain left-aligned, unallocated tail entries -1 (the device steps
        drop writes / mask reads through negative entries)."""
        chain = self._pages[rid]
        if len(chain) > width:
            raise ValueError(f"request {rid}: chain {len(chain)} exceeds "
                             f"table width {width}")
        row = np.full((width,), -1, np.int32)
        row[:len(chain)] = chain
        return row

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        """Occupancy + fragmentation snapshot (serve.py's end-of-run report).

        ``internal_frag`` is the classic paged-memory loss: the fraction of
        *allocated* token slots no live token occupies (last-page slack).
        There is no external fragmentation by construction — any free block
        can serve any request — so the pool also reports ``reserved`` slack
        (blocks promised to admitted requests but not yet written), which is
        what actually gates admission."""
        used_tokens = sum(self._tokens.values())
        live = self.live_blocks
        return {
            "n_blocks": self.n_blocks,
            "block": self.block,
            "free_blocks": self.free_blocks,
            "live_blocks": live,
            "reserved_blocks": self.reserved_blocks,
            "live_requests": len(self._pages),
            "occupancy": live / self.n_blocks,
            "peak_occupancy": self.peak_live / self.n_blocks,
            "internal_frag": (1.0 - used_tokens / (live * self.block)
                              if live else 0.0),
            "frag_at_peak": self.frag_at_peak,
        }
