"""Distributed sequences: the FooPar Table-1 operation algebra in JAX.

A ``DSeq`` is the JAX realization of FooPar's ``DistributedSeq``: a sequence
whose *i*-th element lives on rank *i* of a communication group.  The
communication group is a mesh axis; the SPMD program is a ``shard_map`` body.
Inside that body each process holds its local element, and the Table-1 group
operations are implemented with ``jax.lax`` collectives:

  mapD / zipWithD   local compute (no communication)
  reduceD           psum/pmin/pmax fast path, or a generic binary-tree
                    reduction built from ppermute (log p rounds — the paper's
                    recursive-doubling cost  Θ(log p (t_s + t_w m + T_λ(m))))
  shiftD            ppermute cyclic shift            Θ(t_s + t_w m)
  allGatherD        all_gather                       Θ((t_s + t_w m)(p-1))
  allToAllD         all_to_all                       Θ(t_s log p + t_w m (p-1))
  applyD(i)         one-to-all broadcast (masked psum)  Θ(log p (t_s + t_w m))
  scanD             parallel prefix (Hillis-Steele)  Θ(log p (t_s + t_w m + T_λ(m)))
  reduceScatterD    ring reduce-scatter              Θ((p-1)(t_s + t_w m/p + T_λ(m/p)))
  ringShiftD        ±1 nearest-neighbour shift       Θ(t_s + t_w m)
  allGatherRingD    pipelined ring all-gather        Θ((t_s + t_w m)(p-1))

The scan / reduce-scatter / ring family is the arXiv:1406.6163 extension of
the Table-1 algebra (group communication patterns beyond the 2013 paper).

Deadlock-freedom and race-freedom hold by construction: the ops are pure
functions on a dataflow graph; there is no user-visible message passing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .compat import axis_size, shard_map as _shard_map

Pytree = Any

# ---------------------------------------------------------------------------
# Low-level SPMD group operations (usable directly inside any shard_map body).
# ---------------------------------------------------------------------------


def axis_index(axis: str) -> jax.Array:
    return lax.axis_index(axis)


def _where_bcast(cond: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """where with a scalar predicate, broadcast over the operand rank."""
    return jnp.where(jnp.reshape(cond, (1,) * a.ndim), a, b)


def reduce_d(x: Pytree, op: Callable | str, axis: str, *, root: int | None = None) -> Pytree:
    """FooPar ``reduceD``: reduce the distributed sequence with associative
    ``op``.

    ``op`` may be one of the strings ``'sum' | 'min' | 'max'`` (lowers to the
    native XLA all-reduce, recursive-doubling on a torus) or an arbitrary
    associative callable, in which case a binary-tree reduction is built from
    ``ppermute`` — ``ceil(log2 p)`` rounds, each moving one element of size m:
    the paper's  Θ(log p (t_s + t_w m + T_λ(m))).

    FooPar reduces *to the root*; XLA exposes all-reduce.  Semantics are kept
    (with ``root`` given, non-root processes receive a zero element whose
    value must not be used); cost is identical in Θ.
    """
    if isinstance(op, str):
        fast = {"sum": lax.psum, "min": lax.pmin, "max": lax.pmax}[op]
        out = jax.tree.map(lambda l: fast(l, axis), x)
        if root is None:
            return out
        idx = lax.axis_index(axis)
        return jax.tree.map(lambda l: jnp.where(idx == root, l, jnp.zeros_like(l)), out)

    p = axis_size(axis)
    idx = lax.axis_index(axis)
    rounds = max(1, math.ceil(math.log2(p))) if p > 1 else 0
    for r in range(rounds):
        stride = 1 << r
        block = stride << 1
        # senders: i with i % block == stride and i - stride >= 0
        perm = [(i + stride, i) for i in range(0, p, block) if i + stride < p]
        recv = jax.tree.map(lambda l: lax.ppermute(l, axis, perm), x)
        combined = op(x, recv)
        is_dst = (idx % block == 0) & (idx + stride < p)
        x = jax.tree.map(
            lambda c, old: jnp.where(
                jnp.reshape(is_dst, (1,) * c.ndim), c, old
            ),
            combined,
            x,
        )
    # result now at rank 0; replicate if root is None (broadcast), else mask.
    if root is None:
        return apply_d(x, 0, axis)
    if root != 0:
        x = shift_d(x, root, axis)  # move result from 0 to root (cyclic ok)
    return jax.tree.map(
        lambda l: jnp.where(lax.axis_index(axis) == root, l, jnp.zeros_like(l)), x
    )


def shift_d(x: Pytree, delta: int, axis: str) -> Pytree:
    """FooPar ``shiftD``: cyclic shift by ``delta`` — Θ(t_s + t_w m)."""
    p = axis_size(axis)
    d = delta % p
    if d == 0:
        return x
    perm = [(i, (i + d) % p) for i in range(p)]
    return jax.tree.map(lambda l: lax.ppermute(l, axis, perm), x)


def all_gather_d(x: Pytree, axis: str, *, tiled: bool = False) -> Pytree:
    """FooPar ``allGatherD`` — Θ((t_s + t_w m)(p-1)) on a ring."""
    return jax.tree.map(lambda l: lax.all_gather(l, axis, axis=0, tiled=tiled), x)


def all_to_all_d(x: Pytree, axis: str) -> Pytree:
    """FooPar ``allToAllD``: local leading dim indexes destination rank."""
    return jax.tree.map(
        lambda l: lax.all_to_all(l, axis, split_axis=0, concat_axis=0, tiled=True), x
    )


def apply_d(x: Pytree, i: int | jax.Array, axis: str) -> Pytree:
    """FooPar ``apply(i)``: every process obtains element *i* — a one-to-all
    broadcast, Θ(log p (t_s + t_w m)).  Implemented as the classic masked-psum
    idiom, which XLA lowers to a log-p broadcast tree."""
    idx = lax.axis_index(axis)
    return jax.tree.map(
        lambda l: lax.psum(
            jnp.where(jnp.reshape(idx == i, (1,) * l.ndim), l, jnp.zeros_like(l)),
            axis,
        ),
        x,
    )


def scan_d(x: Pytree, axis: str, op: Callable | None = None, *,
           inclusive: bool = False) -> Pytree:
    """Parallel prefix over the group (arXiv:1406.6163 ``scanD``).

    Hillis-Steele recursive doubling: ``ceil(log2 p)`` rounds of ppermute,
    each combining with the neighbour ``stride`` ranks below —
    Θ(log p (t_s + t_w m + T_λ(m))).  ``op`` is any associative callable
    (default elementwise ``+``).  ``inclusive=False`` (default) returns the
    exclusive prefix: rank 0 gets the identity (zeros — only meaningful for
    ``+``-like ops), rank i gets ``op``-fold of elements 0..i-1.
    """
    op = op or (lambda a, b: a + b)
    idx = lax.axis_index(axis)
    p = axis_size(axis)
    acc = x
    for r in range(max(0, math.ceil(math.log2(p)))):
        stride = 1 << r
        perm = [(i, i + stride) for i in range(p - stride)]
        recv = jax.tree.map(lambda l: lax.ppermute(l, axis, perm), acc)
        take = idx >= stride
        combined = jax.tree.map(lambda a, rv: op(rv, a), acc, recv)
        acc = jax.tree.map(
            lambda c, a: _where_bcast(take, c, a), combined, acc,
        )
    if inclusive:
        return acc
    # convert inclusive -> exclusive (identity = zeros at rank 0)
    shifted = jax.tree.map(
        lambda l: lax.ppermute(l, axis, [(i, i + 1) for i in range(p - 1)]), acc)
    return jax.tree.map(
        lambda s: _where_bcast(idx == 0, jnp.zeros_like(s), s), shifted,
    )


def reduce_scatter_d(x: Pytree, op: Callable | str, axis: str) -> Pytree:
    """``reduceScatterD`` (arXiv:1406.6163): reduce the sequence with ``op``
    and leave rank i holding the i-th chunk of the result (leading dim is
    split p ways).

    ``op == 'sum'`` lowers to the native ``psum_scatter``.  A callable ``op``
    runs the classic ring algorithm: p-1 nearest-neighbour steps, each moving
    one m/p chunk — Θ((p-1)(t_s + t_w m/p + T_λ(m/p))), the bandwidth-optimal
    half of an all-reduce.
    """
    if isinstance(op, str):
        assert op == "sum", op
        return jax.tree.map(
            lambda l: lax.psum_scatter(l, axis, scatter_dimension=0, tiled=True),
            x,
        )

    p = axis_size(axis)
    idx = lax.axis_index(axis)
    ring = [(i, (i + 1) % p) for i in range(p)]
    for l in jax.tree.leaves(x):
        if l.shape[0] % p:
            raise ValueError(
                f"reduce_scatter_d: leading dim {l.shape[0]} must be "
                f"divisible by group size {p}")

    def chunk(l: jax.Array, c: jax.Array) -> jax.Array:
        blk = l.shape[0] // p
        return lax.dynamic_slice_in_dim(l, c * blk, blk, axis=0)

    # chunk c travels the ring from rank c+1 to rank c, accumulating each
    # host's contribution; rank r therefore sends the partial of chunk
    # (r - s - 1) at step s and finishes holding chunk r.
    if p == 1:
        return x
    buf = jax.tree.map(lambda l: chunk(l, (idx - 1) % p), x)
    for s in range(p - 1):
        sent = jax.tree.map(lambda l: lax.ppermute(l, axis, ring), buf)
        c_recv = (idx - s - 2) % p
        buf = jax.tree.map(lambda rv, l: op(rv, chunk(l, c_recv)), sent, x)
    return buf


def ring_shift_d(x: Pytree, axis: str, *, reverse: bool = False) -> Pytree:
    """Nearest-neighbour ring step (±1 cyclic shift) — Θ(t_s + t_w m).

    The building block of the pipelined ("systolic") variants below and of
    Cannon's algorithm: every rank passes its element to rank+1 (or rank-1
    with ``reverse``), so p-1 applications rotate the full sequence past
    every rank with only nearest-neighbour traffic.
    """
    return shift_d(x, -1 if reverse else 1, axis)


def all_gather_ring_d(x: Pytree, axis: str) -> Pytree:
    """Pipelined ring all-gather: p-1 ``ring_shift_d`` steps, concatenating
    the block received at each step — Θ((t_s + t_w m)(p-1)), identical in Θ
    to the native all-gather but expressed in the algebra (and usable with
    compute overlapped between steps, as in pipelined SUMMA)."""
    p = axis_size(axis)
    idx = lax.axis_index(axis)
    parts = [jax.tree.map(lambda l: l, x)]
    buf = x
    for _ in range(p - 1):
        buf = ring_shift_d(buf, axis)
        parts.append(buf)
    # parts[s] is the element of rank (idx - s) % p; roll into rank order so
    # position j of the output holds element j, matching all_gather_d.
    def assemble(*ls):
        stacked = jnp.stack(ls, axis=0)  # (p, ...) in arrival order
        order = (idx - jnp.arange(p)) % p
        return jnp.zeros_like(stacked).at[order].set(stacked)

    return jax.tree.map(lambda *ls: assemble(*ls), *parts)


# ---------------------------------------------------------------------------
# DSeq: the object-oriented face of the algebra (paper §3.3), for use inside
# shard_map bodies.  Chains read like the paper:  seq.mapD(f).reduceD('+').
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DSeq:
    """A distributed sequence bound to communication group ``axis``.

    ``local`` is this process's element (any pytree of arrays).  Element *i*
    of the abstract sequence lives on rank *i* of the mesh axis.
    """

    local: Pytree
    axis: str

    # -- non-communicating ------------------------------------------------
    def mapD(self, f: Callable) -> "DSeq":
        return DSeq(f(self.local), self.axis)

    def mapIdxD(self, f: Callable) -> "DSeq":
        """map with the element index (= rank) as first argument."""
        return DSeq(f(lax.axis_index(self.axis), self.local), self.axis)

    def zipWithD(self, other: "DSeq", f: Callable) -> "DSeq":
        assert other.axis == self.axis, "zipWithD requires the same group"
        return DSeq(f(self.local, other.local), self.axis)

    # -- communicating (Table 1) ------------------------------------------
    def reduceD(self, op: Callable | str, root: int | None = None) -> Pytree:
        return reduce_d(self.local, op, self.axis, root=root)

    def shiftD(self, delta: int) -> "DSeq":
        return DSeq(shift_d(self.local, delta, self.axis), self.axis)

    def allGatherD(self, tiled: bool = False) -> Pytree:
        return all_gather_d(self.local, self.axis, tiled=tiled)

    def allToAllD(self) -> "DSeq":
        return DSeq(all_to_all_d(self.local, self.axis), self.axis)

    def apply(self, i: int | jax.Array) -> Pytree:
        return apply_d(self.local, i, self.axis)

    def scanD(self, op: Callable | None = None, *, inclusive: bool = False) -> "DSeq":
        return DSeq(scan_d(self.local, self.axis, op, inclusive=inclusive), self.axis)

    def reduceScatterD(self, op: Callable | str = "sum") -> "DSeq":
        return DSeq(reduce_scatter_d(self.local, op, self.axis), self.axis)

    def ringShiftD(self, *, reverse: bool = False) -> "DSeq":
        return DSeq(ring_shift_d(self.local, self.axis, reverse=reverse), self.axis)

    def allGatherRingD(self) -> Pytree:
        return all_gather_ring_d(self.local, self.axis)

    # -- introspection -----------------------------------------------------
    @property
    def size(self) -> int:
        return axis_size(self.axis)

    @property
    def rank(self) -> jax.Array:
        return lax.axis_index(self.axis)


def spmd(
    f: Callable,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    *,
    check_vma: bool = False,
):
    """Run ``f`` as a FooPar SPMD program over ``mesh``.

    Thin wrapper over ``jax.shard_map`` — every process executes ``f`` on its
    shard; group operations on DSeq objects are the only communication.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check=check_vma
    )
