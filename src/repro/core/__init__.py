"""FooPar core: distributed-collection algebra, grids, cost model, algorithms.

The paper's primary contribution realized in JAX: DSeq (Table-1 op algebra),
GridN Cartesian process grids, the (t_s, t_w) cost model with TPU constants,
and the two paper algorithms (DNS matmul, Floyd-Warshall) built on them.
"""
from .dseq import (DSeq, spmd, reduce_d, shift_d, all_gather_d, all_to_all_d,
                   apply_d, scan_d, reduce_scatter_d, ring_shift_d,
                   all_gather_ring_d)
from .grid import GridN, Grid2D, Grid3D, make_grid_mesh
from . import costmodel
from .compat import abstract_mesh
from .dns_matmul import dns_matmul, generic_matmul, dns_matmul_pallas
from .summa import (summa_matmul, cannon_matmul, summa_matmul_pallas,
                    cannon_matmul_pallas)
from .summa_pipelined import (summa_matmul_pipelined, cannon_matmul_25d,
                              summa_matmul_pipelined_pallas,
                              cannon_matmul_25d_pallas)
from .floyd_warshall import (floyd_warshall, blocked_floyd_warshall,
                             floyd_warshall_reference)
