"""Cartesian grid abstractions (paper §4.3): GridN / Grid2D / Grid3D.

A grid binds N mesh axes into a Cartesian process grid.  Each process has a
coordinate tuple; ``seq(axis)`` returns the DSeq that is *variable* in that
axis and constant in all the others — the paper's ``xSeq / ySeq / zSeq``.
This is what lets multi-axis algorithms (DNS matmul, Floyd-Warshall) be
written as chained functional ops per axis, with the Table-1 costs applying
per-axis (group size = the axis extent, not p).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence, Tuple

import jax
from jax import lax

from .dseq import DSeq

Pytree = Any


@dataclass(frozen=True)
class GridN:
    """An N-dimensional Cartesian process grid over mesh axes ``axes``.

    Used inside a ``shard_map`` body whose mesh contains those axes.  The
    process's coordinate is ``self.coords`` (a tuple of traced ints).
    """

    axes: Tuple[str, ...]

    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def coords(self) -> Tuple[jax.Array, ...]:
        return tuple(lax.axis_index(a) for a in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(lax.axis_size(a) for a in self.axes)

    def mapD(self, f: Callable[..., Pytree]) -> Pytree:
        """Each process computes ``f(*coords)`` — the paper's
        ``G mapD { case (i, j, k) => ... }`` (non-communicating; lazy/proxy
        data is materialized per-process here)."""
        return f(*self.coords)

    def seq(self, axis: str, local: Pytree) -> DSeq:
        """The distributed sequence variable in ``axis``, constant in the
        remaining coordinates (paper's xSeq/ySeq/zSeq)."""
        assert axis in self.axes
        return DSeq(local, axis)


class Grid2D(GridN):
    def __init__(self, x_axis: str = "x", y_axis: str = "y"):
        super().__init__(axes=(x_axis, y_axis))

    def xSeq(self, local: Pytree) -> DSeq:  # variable in x, fixed y
        return self.seq(self.axes[0], local)

    def ySeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[1], local)


class Grid3D(GridN):
    def __init__(self, x_axis: str = "x", y_axis: str = "y", z_axis: str = "z"):
        super().__init__(axes=(x_axis, y_axis, z_axis))

    def xSeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[0], local)

    def ySeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[1], local)

    def zSeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[2], local)


def make_grid_mesh(shape: Sequence[int], axes: Sequence[str] | None = None) -> jax.sharding.Mesh:
    """Build a device mesh for an N-d grid on the available devices."""
    axes = tuple(axes) if axes is not None else tuple("xyzw"[: len(shape)])
    return jax.make_mesh(tuple(shape), axes)
