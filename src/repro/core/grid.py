"""Cartesian grid abstractions (paper §4.3): GridN / Grid2D / Grid3D.

A grid binds N mesh axes into a Cartesian process grid.  Each process has a
coordinate tuple; ``seq(axis)`` returns the DSeq that is *variable* in that
axis and constant in all the others — the paper's ``xSeq / ySeq / zSeq``.
This is what lets multi-axis algorithms (DNS matmul, Floyd-Warshall) be
written as chained functional ops per axis, with the Table-1 costs applying
per-axis (group size = the axis extent, not p).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .compat import axis_size
from .dseq import DSeq, apply_d, reduce_d, ring_shift_d, shift_d

Pytree = Any


@dataclass(frozen=True)
class RingBcast:
    """An in-flight pipelined ring broadcast along one mesh axis.

    A tree broadcast (``apply_d``) delivers in Θ(log p) but every step of a
    panel loop must wait for the whole tree.  A *ring* broadcast instead
    forwards the element one nearest-neighbour hop per ``step()`` —
    Θ(t_s + t_w m) each — so a caller can interleave hops of panel k+1's
    broadcast with the local multiply of panel k (double buffering): the
    transfer is hidden behind compute instead of serialized with it.

    ``buf`` holds the broadcast value on every rank whose forward ring
    distance from ``src`` is ≤ ``hops``.  Other ranks still hold their own
    local element — no zero-masking is needed, because each rank's hop-h
    select overwrites its buffer from its predecessor exactly when the
    value arrives (distance h), before anything reads it.  After ``p - 1``
    steps the value is everywhere and ``value`` may be read.
    """

    buf: Pytree
    src: Any  # int | jax.Array
    hops: int
    axis: str

    @classmethod
    def start(cls, local: Pytree, src, axis: str) -> "RingBcast":
        return cls(buf=local, src=src, hops=0, axis=axis)

    def step(self) -> "RingBcast":
        """Advance one nearest-neighbour hop (``ring_shift_d``)."""
        p = axis_size(self.axis)
        if self.hops >= p - 1:
            return self
        idx = lax.axis_index(self.axis)
        # the value arrives at ring distance d exactly at hop d; lax.rem on
        # the made-nonnegative distance avoids jnp.%'s sign-fixup op chain
        arriving = lax.rem(idx - self.src + p, p) == self.hops + 1
        recv = ring_shift_d(self.buf, self.axis)
        buf = jax.tree.map(
            lambda b, r: jnp.where(jnp.reshape(arriving, (1,) * b.ndim), r, b),
            self.buf, recv,
        )
        return RingBcast(buf=buf, src=self.src, hops=self.hops + 1, axis=self.axis)

    @property
    def done(self) -> bool:
        return self.hops >= axis_size(self.axis) - 1

    @property
    def value(self) -> Pytree:
        assert self.done, (self.hops, self.axis)
        return self.buf


@dataclass(frozen=True)
class GridN:
    """An N-dimensional Cartesian process grid over mesh axes ``axes``.

    Used inside a ``shard_map`` body whose mesh contains those axes.  The
    process's coordinate is ``self.coords`` (a tuple of traced ints).
    """

    axes: Tuple[str, ...]

    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def coords(self) -> Tuple[jax.Array, ...]:
        return tuple(lax.axis_index(a) for a in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(axis_size(a) for a in self.axes)

    def mapD(self, f: Callable[..., Pytree]) -> Pytree:
        """Each process computes ``f(*coords)`` — the paper's
        ``G mapD { case (i, j, k) => ... }`` (non-communicating; lazy/proxy
        data is materialized per-process here)."""
        return f(*self.coords)

    def seq(self, axis: str, local: Pytree) -> DSeq:
        """The distributed sequence variable in ``axis``, constant in the
        remaining coordinates (paper's xSeq/ySeq/zSeq)."""
        assert axis in self.axes
        return DSeq(local, axis)


class Grid2D(GridN):
    """A q_x × q_y process grid.  Convention: the ``x`` axis indexes the
    process *row* i, ``y`` the process *column* j — so a "row" of the grid is
    the communication group that varies in y (all columns of one row), and
    row-wise collectives run over the y axis.

    The row/column broadcast + reduce helpers below are the primitives of
    the 2D matmul family (SUMMA's k-panel broadcasts, Cannon's ring shifts)
    and of the 2D Floyd-Warshall — paper §4.3/§5."""

    def __init__(self, x_axis: str = "x", y_axis: str = "y"):
        super().__init__(axes=(x_axis, y_axis))

    @property
    def row_axis(self) -> str:  # the axis a row-wise collective runs over
        return self.axes[1]

    @property
    def col_axis(self) -> str:
        return self.axes[0]

    def xSeq(self, local: Pytree) -> DSeq:  # variable in x, fixed y
        return self.seq(self.axes[0], local)

    def ySeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[1], local)

    # -- 2D collective helpers (SUMMA / Cannon / FW building blocks) -------
    def bcast_row(self, local: Pytree, src_col: int | jax.Array) -> Pytree:
        """One-to-all broadcast within each process row: every process of row
        i receives the element held at (i, src_col) — Θ(log q_y (t_s + t_w m))."""
        return apply_d(local, src_col, self.row_axis)

    def bcast_col(self, local: Pytree, src_row: int | jax.Array) -> Pytree:
        """Broadcast within each process column from row ``src_row``."""
        return apply_d(local, src_row, self.col_axis)

    def reduce_row(self, local: Pytree, op: Callable | str = "sum",
                   root: int | None = None) -> Pytree:
        """reduceD over each process row (the y group)."""
        return reduce_d(local, op, self.row_axis, root=root)

    def reduce_col(self, local: Pytree, op: Callable | str = "sum",
                   root: int | None = None) -> Pytree:
        return reduce_d(local, op, self.col_axis, root=root)

    def shift_row(self, local: Pytree, delta: int) -> Pytree:
        """Cyclic shift within each process row (Cannon's A-movement)."""
        return shift_d(local, delta, self.row_axis)

    def shift_col(self, local: Pytree, delta: int) -> Pytree:
        return shift_d(local, delta, self.col_axis)

    # -- pipelined (double-buffered) ring broadcasts -----------------------
    def bcast_row_ring_start(self, local: Pytree, src_col) -> RingBcast:
        """Begin a pipelined ring broadcast within each process row from
        column ``src_col``.  Unlike ``bcast_row`` (a log-tree ``apply_d``),
        the transfer advances one nearest-neighbour hop per
        ``bcast_row_ring_next`` call, so the caller can issue panel k+1's
        hops before panel k's local multiply (pipelined SUMMA)."""
        return RingBcast.start(local, src_col, self.row_axis)

    def bcast_row_ring_next(self, st: RingBcast) -> RingBcast:
        assert st.axis == self.row_axis
        return st.step()

    def bcast_col_ring_start(self, local: Pytree, src_row) -> RingBcast:
        """Column-wise twin of ``bcast_row_ring_start`` (over the x axis)."""
        return RingBcast.start(local, src_row, self.col_axis)

    def bcast_col_ring_next(self, st: RingBcast) -> RingBcast:
        assert st.axis == self.col_axis
        return st.step()

    def skew(self, local: Pytree, *, by_row: bool, scale: int = 1) -> Pytree:
        """Cannon's alignment step as one grid-wide ppermute.

        ``by_row=True`` sends (i, j) → (i, j - i·scale mod q_y) — row i's
        blocks rotate left by i·scale (A's skew); ``by_row=False`` sends
        (i, j) → (i - j·scale mod q_x, j) (B's skew).  A single ppermute over
        the linearized grid, Θ(t_s + t_w m): per-row distances differ, which
        a single-axis shift cannot express.
        """
        qx, qy = self.shape
        perm = []
        for i in range(qx):
            for j in range(qy):
                if by_row:
                    dst = (i, (j - i * scale) % qy)
                else:
                    dst = ((i - j * scale) % qx, j)
                perm.append((i * qy + j, dst[0] * qy + dst[1]))
        return jax.tree.map(lambda l: lax.ppermute(l, self.axes, perm), local)


class Grid3D(GridN):
    def __init__(self, x_axis: str = "x", y_axis: str = "y", z_axis: str = "z"):
        super().__init__(axes=(x_axis, y_axis, z_axis))

    def xSeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[0], local)

    def ySeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[1], local)

    def zSeq(self, local: Pytree) -> DSeq:
        return self.seq(self.axes[2], local)


def make_grid_mesh(shape: Sequence[int], axes: Sequence[str] | None = None) -> jax.sharding.Mesh:
    """Build a device mesh for an N-d grid on the available devices."""
    axes = tuple(axes) if axes is not None else tuple("xyzw"[: len(shape)])
    return jax.make_mesh(tuple(shape), axes)
