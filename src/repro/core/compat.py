"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern spelling (``jax.shard_map`` with
``check_vma`` / ``axis_names``, ``lax.axis_size``, size-and-names
``AbstractMesh``).  Older installed versions (0.4.x) expose the same
functionality under different names; everything version-sensitive funnels
through this module so call sites stay on one spelling.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax
from jax import lax


def shard_map(
    f: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    axis_names: Iterable[str] | None = None,
    check: bool = False,
) -> Callable:
    """``jax.shard_map`` with partial-manual support on both API generations.

    ``axis_names`` lists the *manual* axes (modern spelling); on 0.4.x it is
    translated to the complementary ``auto`` set.  ``check`` maps to
    ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check, **kw,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check, **kw,
    )


def axis_size(axis: str | tuple) -> int:
    """Static size of a mesh axis from inside an SPMD body.

    ``lax.axis_size`` where available; otherwise ``psum`` of a Python scalar,
    which constant-folds to the concrete group size.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def abstract_mesh(shape: tuple, axis_names: tuple) -> "jax.sharding.AbstractMesh":
    """``AbstractMesh`` across the (name, size)-pairs / sizes-plus-names split."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axis_names))
