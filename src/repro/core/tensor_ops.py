"""Tensor-parallel matmuls expressed in the FooPar algebra (first-class
integration of the paper's technique into the LM framework).

A Megatron-style TP layer is exactly a FooPar chain over the ``model`` axis:

  column-parallel  y_shard = x @ W_shard            — mapD (no communication)
  row-parallel     y = Σ_k x_shard @ W_shard        — zipWithD (·) ∘ reduceD (+)

which is the same ``mapD/zipWithD → reduceD`` pattern as the paper's matrix
multiplication (§4.2).  These are implemented with *partial-manual*
``shard_map``: only the TP axis is manual (the algebra's communication group);
batch/data axes stay auto-sharded so the ops compose inside pjit programs.

``choose_tp_strategy`` ranks the two layouts with the Table-1 cost model —
the paper's "analyzability" claim used as a runtime decision procedure.
"""
from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import costmodel
from .compat import shard_map as _shard_map
from .dseq import DSeq


def _manual(f, mesh, in_specs, out_specs, axis):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      axis_names={axis}, check=False)


def foopar_matmul_row(x: jax.Array, w: jax.Array, *, mesh, axis: str = "model",
                      preferred_element_type=jnp.float32) -> jax.Array:
    """Row-parallel: x (..., k) with k sharded over ``axis``; w (k, n) sharded
    on k.  FooPar:  zipWithD (·) then reduceD (+)  — one all-reduce of the
    (…, n) output: Θ(log p (t_s + t_w m)) latency, 2m(p-1)/p bandwidth."""

    def body(xl, wl):
        partial_ = DSeq(xl, axis).zipWithD(
            DSeq(wl, axis),
            lambda a, b: jnp.matmul(a, b, preferred_element_type=preferred_element_type),
        )
        return partial_.reduceD("sum")

    nx = x.ndim
    return _manual(body, mesh,
                   in_specs=(P(*([None] * (nx - 1) + [axis])), P(axis, None)),
                   out_specs=P(*([None] * nx)), axis=axis)(x, w)


def foopar_matmul_col(x: jax.Array, w: jax.Array, *, mesh, axis: str = "model",
                      preferred_element_type=jnp.float32) -> jax.Array:
    """Column-parallel: w (k, n) sharded on n; output (…, n) sharded on n.
    FooPar: pure mapD — zero communication."""

    def body(xl, wl):
        return DSeq((xl, wl), axis).mapD(
            lambda t: jnp.matmul(t[0], t[1], preferred_element_type=preferred_element_type)
        ).local

    nx = x.ndim
    return _manual(body, mesh,
                   in_specs=(P(*([None] * nx)), P(None, axis)),
                   out_specs=P(*([None] * (nx - 1) + [axis])), axis=axis)(x, w)


def choose_tp_strategy(m_tokens: int, k: int, n: int, p: int,
                       bytes_per_elt: int = 2) -> Literal["row", "col"]:
    """Rank row- vs column-parallel with the Table-1 cost model.

    row: all-reduce of (m_tokens, n) output; col: none now, but the activation
    stays sharded (cost deferred to the consumer — modeled as an eventual
    all-gather of the same size).  The decision reduces to whether the
    *consumer* contracts over n (then 'col' is free) — callers pass the
    effective sizes; ties break to 'col' (lazier)."""
    m_bytes = m_tokens * n * bytes_per_elt
    row_cost = costmodel.t_all_reduce(m_bytes, p)
    col_cost = costmodel.t_all_gather(m_bytes / p, p)
    return "row" if row_cost < col_cost else "col"


def dns_matmul_2d(x: jax.Array, w: jax.Array, *, mesh,
                  contract_axis: str = "data", out_axis: str = "model",
                  preferred_element_type=jnp.float32) -> jax.Array:
    """2.5D/DNS-flavored matmul for pjit programs (beyond paper): contract
    dimension sharded over ``contract_axis`` *and* output sharded over
    ``out_axis`` — the LM-mesh projection of the paper's 3D decomposition
    (the q³ grid's z-axis ≙ contract_axis, x/y ≙ batch × out).  Reduces the
    all-reduce size by p_out compared to plain row-parallel."""

    def body(xl, wl):
        part = jnp.matmul(xl, wl, preferred_element_type=preferred_element_type)
        return jax.lax.psum(part, contract_axis)

    nx = x.ndim
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(*([None] * (nx - 1) + [contract_axis])), P(contract_axis, out_axis)),
        out_specs=P(*([None] * (nx - 1) + [out_axis])),
        axis_names={contract_axis, out_axis}, check=False,
    )(x, w)
