"""2D parallel matrix multiplication on the FooPar algebra: SUMMA + Cannon.

The paper's §4 family covers the 1D generic algorithm (Θ(p^{5/3})
isoefficiency) and the 3D DNS algorithm (Θ(p log p) isoefficiency but p^{1/3}
-fold replication of both operands).  This module adds the classic 2D points
of the scenario space, both expressed with the ``Grid2D`` helpers:

* ``summa_matmul``  — outer-product SUMMA (van de Geijn & Watts): L panel
  steps, each a row-broadcast of an A panel and a column-broadcast of a B
  panel, accumulated locally.  Works on rectangular q_x × q_y grids (panel
  count L = lcm(q_x, q_y)).  Memory per process: Θ(n²/p) — no replication.
* ``cannon_matmul`` — Cannon's algorithm: one skew ppermute per operand,
  then L multiply-and-ring-shift steps.  Nearest-neighbour traffic only
  (Θ(t_s + t_w m) per step vs SUMMA's log-factor broadcasts), same Θ(n²/√p)
  per-process memory.  Generalized to rectangular grids by panel windows of
  size L/q_y (A) and L/q_x (B).

Together with ``dns_matmul`` (3D) and ``core/summa_pipelined.py`` (the
overlapped/replicated tier) the repo covers the full five-point parallel
matmul scenario space.  Per process on p chips, problem size n, replication
factor c (costs from ``core/costmodel``):

  ================  =========  ==============  =======================
  variant           memory     communication   schedule / overlap
  ================  =========  ==============  =======================
  SUMMA             3n²/p      Θ(n²/√p·log √p) L tree bcasts, serial
                                               with compute
  SUMMA-pipelined   3n²/p (×2  Θ(n²/√p) ring   per-step max(t_comm,
                    panel buf) hops            t_comp) + Θ(√p) fill
  Cannon            3n²/p      Θ(n²/√p)        nearest-neighbour only,
                                               serial with compute
  Cannon-2.5D       3c·n²/p    Θ(n²/√(c·p))    q/c steps per replica
                                               layer + sum over c
  DNS (3D)          3n²/p^2/3  Θ(n²/p^{2/3}    two log-tree bcasts +
                               ·log p^{1/3})   one tree reduce
  ================  =========  ==============  =======================

The cost model picks SUMMA/Cannon when memory is tight (no replication),
the pipelined variant whenever per-step compute can hide a ring hop (large
n/√p), 2.5D when spare memory (c > 1 copies fit) can buy bandwidth, and
DNS when memory is plentiful and isoefficiency (Θ(p log p)) dominates.

All variants accept a ``local_matmul`` kernel (e.g. the Pallas MXU kernel)
exactly like ``dns_matmul`` plus a ``local_matmul_acc(a, b, c)`` fused
accumulate kernel (``kernels.ops.matmul_acc``) used by the Pallas wrappers
so the panel loop updates C in place; cost formulas live in
``costmodel.summa_matmul_cost`` / ``cannon_matmul_cost`` /
``summa_pipelined_cost`` / ``cannon_25d_cost`` and the isoefficiency
comparison in ``costmodel.isoefficiency_matmul_*``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, List

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .dseq import spmd
from .grid import Grid2D


def _skew_panels(g: Grid2D, panels: List[jax.Array], *, qx: int, qy: int,
                 L: int, operand: str) -> List[jax.Array]:
    """Cannon's alignment, at panel granularity, on a (possibly rectangular)
    grid.  After skewing, process (i, j) holds the window of panels
    ``base(i,j) + s (mod L)`` where ``base = i·L/q_x + j·L/q_y`` — exactly
    the panels its first L/len(panels) multiply steps consume.

    With one panel per process the whole window moves as one block and the
    alignment is a single ``Grid2D.skew`` ppermute (distance i·L/q_x per row
    for A, j·L/q_y per column for B).  Multi-panel windows interleave panels
    from different source processes, but for a fixed destination slot every
    source rank contributes exactly one of its local slots — so each rank
    *locally selects* the slot it must send (a dynamic index into the
    stacked window, no communication) and the whole dest slot moves as one
    merged grid-wide ppermute: n_slots ppermutes total instead of n_slots²
    partial ones with zero-fill adds.
    """
    n_slots = len(panels)
    if n_slots == 1:
        return [g.skew(panels[0], by_row=operand == "A",
                       scale=(L // qx) if operand == "A" else (L // qy))]
    stacked = jnp.stack(panels, axis=0)
    coords = g.coords[0] * qy + g.coords[1]  # linearized own rank
    out = []
    for ds in range(n_slots):
        perm = []                     # one merged permutation per dest slot
        send_slot = [-1] * (qx * qy)  # which local slot rank r contributes
        for i in range(qx):
            for j in range(qy):
                k = (i * (L // qx) + j * (L // qy) + ds) % L
                owner = k // n_slots
                src = (i, owner) if operand == "A" else (owner, j)
                src_lin = src[0] * qy + src[1]
                assert send_slot[src_lin] == -1, (
                    f"rank {src} would send twice in merged skew "
                    f"permutation (operand={operand}, dest slot {ds})")
                send_slot[src_lin] = k % n_slots
                perm.append((src_lin, i * qy + j))
        assert all(s >= 0 for s in send_slot)
        sel = jnp.asarray(send_slot)[coords]
        payload = lax.dynamic_index_in_dim(stacked, sel, 0, keepdims=False)
        out.append(lax.ppermute(payload, g.axes, perm))
    return out


def _default_mm(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _make_mm_acc(local_matmul: Callable | None,
                 local_matmul_acc: Callable | None) -> Callable:
    """``(a, b, c) -> c + a @ b`` from whichever kernel the caller gave."""
    if local_matmul_acc is not None:
        return local_matmul_acc
    mm = local_matmul or _default_mm
    return lambda a, b, c: c + mm(a, b)


def summa_matmul(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                 *, local_matmul: Callable | None = None,
                 local_matmul_acc: Callable | None = None,
                 row_axis: str = "x", col_axis: str = "y") -> jax.Array:
    """SUMMA on a q_x × q_y process grid.

    Data layout (the static process↔data mapping, as with DNS): A and B both
    arrive block-partitioned P(x, y) — process (i, j) holds the (i, j) block
    of each.  The contraction dimension is cut into L = lcm(q_x, q_y) panels
    of width n/L; panel k of A lives in block-column k·q_y/L, panel k of B in
    block-row k·q_x/L.  For k = 0..L-1::

        a_k = bcast_row(A-panel k,  src_col = owner column of k)
        b_k = bcast_col(B-panel k,  src_row = owner row of k)
        C  += a_k @ b_k                          (local_matmul)

    Per-process cost: L row-broadcasts of (n/q_x × n/L) + L column-broadcasts
    of (n/L × n/q_y) + the same 2n³/p flops as every variant.
    """
    mm_acc = _make_mm_acc(local_matmul, local_matmul_acc)
    qx, qy = mesh.shape[row_axis], mesh.shape[col_axis]
    L = math.lcm(qx, qy)
    n_k = A.shape[1]
    assert n_k % L == 0 and A.shape[1] == B.shape[0], (A.shape, B.shape, L)

    def body(a_blk, b_blk):
        g = Grid2D(row_axis, col_axis)
        w = a_blk.shape[1] // (L // qy)          # panel width n/L
        c = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        for k in range(L):
            a_off = (k % (L // qy)) * w
            b_off = (k % (L // qx)) * w
            a_k = g.bcast_row(a_blk[:, a_off:a_off + w], k // (L // qy))
            b_k = g.bcast_col(b_blk[b_off:b_off + w, :], k // (L // qx))
            c = mm_acc(a_k, b_k, c)
        return c

    fn = spmd(body, mesh,
              in_specs=(P(row_axis, col_axis), P(row_axis, col_axis)),
              out_specs=P(row_axis, col_axis))
    return fn(A, B)


def cannon_matmul(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                  *, local_matmul: Callable | None = None,
                  local_matmul_acc: Callable | None = None,
                  row_axis: str = "x", col_axis: str = "y") -> jax.Array:
    """Cannon's algorithm on a q_x × q_y grid (square or rectangular).

    Square grid (the classic): skew row i of A left by i and column j of B
    up by j (one ppermute each), then q steps of ``C += a @ b`` followed by
    a single ring shift of A along the row and B along the column.  All
    traffic after the skew is nearest-neighbour — no broadcast trees, which
    is Cannon's advantage over SUMMA on torus interconnects.

    Rectangular grids run the same schedule over L = lcm(q_x, q_y) panel
    steps: A's local block is a window of L/q_y panels consumed in order,
    ring-shifted one block every L/q_y steps (and symmetrically for B).
    """
    mm_acc = _make_mm_acc(local_matmul, local_matmul_acc)
    qx, qy = mesh.shape[row_axis], mesh.shape[col_axis]
    L = math.lcm(qx, qy)
    assert A.shape[1] % L == 0 and A.shape[1] == B.shape[0], (A.shape, B.shape, L)

    def body(a_blk, b_blk):
        g = Grid2D(row_axis, col_axis)
        w = a_blk.shape[1] // (L // qy)
        a_slots = [a_blk[:, s * w:(s + 1) * w] for s in range(L // qy)]
        b_slots = [b_blk[s * w:(s + 1) * w, :] for s in range(L // qx)]
        a_slots = _skew_panels(g, a_slots, qx=qx, qy=qy, L=L, operand="A")
        b_slots = _skew_panels(g, b_slots, qx=qx, qy=qy, L=L, operand="B")
        c = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        for t in range(L):
            c = mm_acc(a_slots[t % len(a_slots)], b_slots[t % len(b_slots)], c)
            if t == L - 1:
                break
            if (t + 1) % len(a_slots) == 0:   # window exhausted: pull from j+1
                a_slots = [g.shift_row(s, -1) for s in a_slots]
            if (t + 1) % len(b_slots) == 0:
                b_slots = [g.shift_col(s, -1) for s in b_slots]
        return c

    fn = spmd(body, mesh,
              in_specs=(P(row_axis, col_axis), P(row_axis, col_axis)),
              out_specs=P(row_axis, col_axis))
    return fn(A, B)


def summa_matmul_pallas(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                        *, interpret: bool = True) -> jax.Array:
    """SUMMA with the accumulate-in-place Pallas MXU kernel (the per-panel
    ``C += A_k B_k`` never materializes a separate product temporary)."""
    from repro.kernels.ops import matmul_acc

    return summa_matmul(A, B, mesh,
                        local_matmul_acc=partial(matmul_acc, interpret=interpret))


def cannon_matmul_pallas(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                         *, interpret: bool = True) -> jax.Array:
    """Cannon with the accumulate-in-place Pallas MXU kernel."""
    from repro.kernels.ops import matmul_acc

    return cannon_matmul(A, B, mesh,
                         local_matmul_acc=partial(matmul_acc, interpret=interpret))
