"""Parallel Floyd-Warshall all-pairs shortest paths (paper §5) + a blocked
beyond-paper variant.

* ``floyd_warshall``          — paper Algorithm 3, faithful: n iterations, per
  iteration one pivot-row and one pivot-column broadcast (size B = n/√p) over
  the respective grid axis, then a rank-1 (min, +) update of the local block.
  T_p = Θ(n (B + (t_s + t_w B) log √p + B²/…)), isoefficiency Θ((√p log p)³).

* ``blocked_floyd_warshall``  — beyond paper: the classical 3-phase blocked
  FW mapped onto the same 2D grid algebra.  q rounds instead of n; per round
  3 block broadcasts (size B²) and (min,+) *matrix* products as local work,
  which the Pallas ``minplus`` kernel tiles for VMEM.  Latency term drops
  from 2n·log q·t_s to 3q·log q·t_s; local work becomes blocked.

Both use only Table-1 operations: apply (broadcast) + mapD updates.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .dseq import apply_d, spmd

INF = jnp.inf


def _local_fw(block: jax.Array) -> jax.Array:
    """Sequential FW closure of one (B, B) block (used on the pivot diagonal)."""
    b = block.shape[0]

    def step(k, d):
        row = lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, B)
        col = lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (B, 1)
        return jnp.minimum(d, col + row)

    return lax.fori_loop(0, b, step, block)


def _minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(min, +) matrix product: out[i,j] = min_k a[i,k] + b[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def floyd_warshall(D: jax.Array, mesh: jax.sharding.Mesh,
                   x_axis: str = "x", y_axis: str = "y") -> jax.Array:
    """Paper Algorithm 3.  ``D`` is the (n, n) weight matrix (∞ for absent
    edges, 0 diagonal), block-distributed over a (√p, √p) grid.

    Per pivot k:
      ik = grid.xSeq.mapD(_(k % B)).apply(k / B)   # pivot-row segment
      kj = grid.ySeq.mapD(col k % B).apply(k / B)  # pivot-col segment
      block = min(block, kj ⊕ ik)                  # rank-1 (min,+) update
    """
    q = mesh.shape[x_axis]
    n = D.shape[0]
    assert D.shape == (n, n) and n % q == 0

    def body(block):
        b = block.shape[0]

        def step(k, blk):
            kb, kq = k % b, k // b
            # pivot row segment: lives at grid row kq, broadcast down columns
            row = lax.dynamic_slice_in_dim(blk, kb, 1, axis=0)[0]      # (B,)
            ik = apply_d(row, kq, x_axis)
            # pivot col segment: lives at grid col kq, broadcast along rows
            col = lax.dynamic_slice_in_dim(blk, kb, 1, axis=1)[:, 0]   # (B,)
            kj = apply_d(col, kq, y_axis)
            return jnp.minimum(blk, kj[:, None] + ik[None, :])

        return lax.fori_loop(0, n, step, block)

    return spmd(body, mesh, in_specs=P(x_axis, y_axis), out_specs=P(x_axis, y_axis))(D)


def blocked_floyd_warshall(D: jax.Array, mesh: jax.sharding.Mesh,
                           x_axis: str = "x", y_axis: str = "y",
                           minplus: Callable | None = None) -> jax.Array:
    """3-phase blocked FW on the 2D grid algebra (beyond paper).

    Round kb (one per block-column, q total):
      phase 1: diagonal block (kb, kb) is FW-closed;
      phase 2: pivot row panel D[kb, j] and col panel D[i, kb] updated with it;
      phase 3: every block D[i, j] ← min(D[i,j], D[i,kb] ⊗ D[kb,j]).
    Broadcasts: row panel down columns, then diagonal along rows (2 hops),
    col panel along rows — all Table-1 ``apply``.
    """
    mp = minplus or _minplus_ref
    q = mesh.shape[x_axis]
    n = D.shape[0]
    assert n % q == 0

    def body(block):
        def round_(kb, blk):
            xi = lax.axis_index(x_axis)
            yj = lax.axis_index(y_axis)
            # --- broadcast pre-round panels -----------------------------
            row_panel = apply_d(blk, kb, x_axis)          # D[kb, j] at all (i, j)
            diag = apply_d(row_panel, kb, y_axis)         # D[kb, kb] everywhere
            col_panel = apply_d(blk, kb, y_axis)          # D[i, kb]
            # --- phase 1: close the diagonal (computed redundantly, SPMD) --
            diag = _local_fw(diag)
            # --- phase 2: update panels with the closed diagonal ----------
            row_panel = jnp.minimum(row_panel, mp(diag, row_panel))
            col_panel = jnp.minimum(col_panel, mp(col_panel, diag))
            # --- phase 3: update all blocks -------------------------------
            new_blk = jnp.minimum(blk, mp(col_panel, row_panel))
            # pivot row/col/diag processes take their panel results instead
            new_blk = jnp.where(xi == kb, row_panel, new_blk)
            new_blk = jnp.where(yj == kb, col_panel, new_blk)
            new_blk = jnp.where((xi == kb) & (yj == kb), diag, new_blk)
            return new_blk

        return lax.fori_loop(0, q, round_, block)

    return spmd(body, mesh, in_specs=P(x_axis, y_axis), out_specs=P(x_axis, y_axis))(D)


def floyd_warshall_reference(D: jax.Array) -> jax.Array:
    """Single-device oracle (same math, no distribution)."""
    return _local_fw(D)
