"""Overlap-pipelined SUMMA and 2.5D (replicated) Cannon.

These are the two remaining points of the matmul scenario space between the
2D family (``core/summa.py``) and 3D DNS (``core/dns_matmul.py``):

* ``summa_matmul_pipelined`` — SUMMA with the per-panel log-tree broadcasts
  replaced by *double-buffered ring broadcasts* (``Grid2D.bcast_row_ring_*``
  built on ``dseq.ring_shift_d``).  The full ring transfer of panel k+1 is
  issued *before* panel k's local multiply, so the Θ(t_w·n²/(L·√p)) per-step
  transfer is independent of the multiply in the dataflow graph and the
  scheduler can hide it behind compute: per-step cost max(t_comm, t_comp)
  instead of t_comm + t_comp, plus a one-time Θ(√p) pipeline-fill latency
  (``costmodel.summa_pipelined_cost``).
* ``cannon_matmul_25d`` — Cannon with c-fold operand replication on a
  q × q × c mesh (Solomonik-Demmel 2.5D).  Each replica layer l runs q/c of
  the q Cannon steps (those with k ≡ l·q/c …), after a layer-dependent skew;
  a final sum over the replication axis assembles C.  Memory per process is
  c× the 2D algorithms' Θ(n²/p) and per-process communication drops to
  Θ(n²/√(c·p)) — the exact interpolation DNS (c = p^{1/3}) ↔ Cannon (c = 1)
  predicted by ``costmodel.cannon_25d_cost``.

Both accept ``local_matmul``/``local_matmul_acc`` kernels; the Pallas
wrappers use the accumulate-in-place MXU kernel (``kernels.ops.matmul_acc``)
so the k-step ``C += A_k B_k`` loop never materializes a separate product
temporary.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .dseq import spmd
from .grid import Grid2D, Grid3D
from .summa import _make_mm_acc


def summa_matmul_pipelined(A: jax.Array, B: jax.Array,
                           mesh: jax.sharding.Mesh, *,
                           local_matmul: Callable | None = None,
                           local_matmul_acc: Callable | None = None,
                           row_axis: str = "x", col_axis: str = "y") -> jax.Array:
    """SUMMA with the per-panel tree broadcasts replaced by ring transfers
    (overlap pipelining).

    Same data layout and result as ``summa_matmul`` (both operands
    block-partitioned P(x, y), L = lcm(q_x, q_y) panel steps) but process
    (i, j) consumes the contraction panels in *rotated* order
    k(t) = (j·L/q_y + t) mod L — addition commutes, so every rank may
    accumulate in its own order.  That rotation removes the A broadcast
    entirely: each rank starts on its own A window (the steady state of a
    filled ring pipeline) and pulls the next window with a single
    nearest-neighbour ``shift_row`` hop — Θ(t_s + t_w m) vs the tree's
    Θ(log q (t_s + t_w m)).  The B panel for step t (its source row is the
    column-dependent owner of k(t)) travels as a double-buffered ring
    broadcast (``Grid2D.bcast_col_ring_start/next`` on ``ring_shift_d``),
    and both transfers for step t+1 are issued *before* step t's local
    multiply: the multiply consumes completed buffers while the next
    transfer is in flight, so the per-step cost is max(t_comm, t_comp)
    instead of their sum (``costmodel.summa_pipelined_cost``).
    """
    mm_acc = _make_mm_acc(local_matmul, local_matmul_acc)
    qx, qy = mesh.shape[row_axis], mesh.shape[col_axis]
    L = math.lcm(qx, qy)
    assert A.shape[1] % L == 0 and A.shape[1] == B.shape[0], (A.shape, B.shape, L)

    # step t at process column j consumes panel k = (j·wa + t) mod L; its
    # owner row and window offset are precomputed host-side so the traced
    # body does two (L,)-row gathers instead of a rem/div chain per step
    # (each traced scalar op is a dispatch thunk on every device).
    wa, wb = L // qy, L // qx
    ks = (np.arange(qy)[:, None] * wa + np.arange(L)[None, :]) % L

    def body(a_blk, b_blk):
        g = Grid2D(row_axis, col_axis)
        w = a_blk.shape[1] // wa           # panel width n_k / L
        j = lax.axis_index(g.row_axis)     # own process column
        a_slots = [a_blk[:, s * w:(s + 1) * w] for s in range(wa)]
        b_stack = jnp.stack([b_blk[s * w:(s + 1) * w, :] for s in range(wb)])
        srcs = jnp.asarray(ks // wb, jnp.int32)[j]   # (L,) owner rows
        offs = jnp.asarray(ks % wb, jnp.int32)[j]    # (L,) window offsets

        def start_b(t):
            """Issue the full ring broadcast of step t's B panel (its source
            row is this column's owner of panel k(j, t))."""
            st = g.bcast_col_ring_start(b_stack[offs[t]], srcs[t])
            for _ in range(qx - 1):
                st = g.bcast_col_ring_next(st)
            return st.value

        c = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        b_next = start_b(0)
        for t in range(L):
            a_t, b_t = a_slots[t % wa], b_next
            if t + 1 < L:                  # double buffer: step t+1's
                b_next = start_b(t + 1)    # transfers precede this multiply
                if (t + 1) % wa == 0:      # A window exhausted: pull from j+1
                    a_slots = [g.shift_row(s, -1) for s in a_slots]
            c = mm_acc(a_t, b_t, c)
        return c

    fn = spmd(body, mesh,
              in_specs=(P(row_axis, col_axis), P(row_axis, col_axis)),
              out_specs=P(row_axis, col_axis))
    return fn(A, B)


def _skew_25d(g: Grid3D, local: jax.Array, *, q: int, c: int, steps: int,
              operand: str) -> jax.Array:
    """2.5D Cannon alignment: dest (i, j, l) receives the block its layer's
    first step consumes — A[i, (i+j+l·steps) mod q] or B[(i+j+l·steps) mod q, j]
    — as one grid-wide ppermute (the layer-dependent distance makes this
    inexpressible as per-axis shifts)."""
    perm = []
    for i in range(q):
        for j in range(q):
            for l in range(c):
                k0 = (i + j + l * steps) % q
                src = (i, k0, l) if operand == "A" else (k0, j, l)
                perm.append((src[0] * q * c + src[1] * c + src[2],
                             i * q * c + j * c + l))
    return jax.tree.map(lambda x: lax.ppermute(x, g.axes, perm), local)


def cannon_matmul_25d(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                      *, local_matmul: Callable | None = None,
                      local_matmul_acc: Callable | None = None,
                      row_axis: str = "x", col_axis: str = "y",
                      rep_axis: str = "z") -> jax.Array:
    """2.5D Cannon on a q × q × c mesh (c = extent of ``rep_axis``).

    Both operands arrive block-partitioned P(x, y) and *replicated* over the
    c replica layers (the 2.5D memory premium).  Layer l skews for Cannon
    step l·(q/c) and runs q/c multiply-and-ring-shift steps — the q-step
    Cannon schedule is split c ways across layers instead of run serially —
    then the partial C's are summed over the replication axis.  c = 1 is
    exactly ``cannon_matmul`` on a square grid; c = q is the DNS corner
    (one multiply per layer, all parallelism from the reduction).
    """
    mm_acc = _make_mm_acc(local_matmul, local_matmul_acc)
    q, qy = mesh.shape[row_axis], mesh.shape[col_axis]
    c = mesh.shape[rep_axis]
    assert q == qy, f"2.5D Cannon needs a square x,y grid, got {q}x{qy}"
    assert q % c == 0, f"replication factor {c} must divide grid side {q}"
    steps = q // c
    assert A.shape[1] % q == 0 and A.shape[1] == B.shape[0], (A.shape, B.shape, q)

    def body(a_blk, b_blk):
        g = Grid3D(row_axis, col_axis, rep_axis)
        g2 = Grid2D(row_axis, col_axis)
        a = _skew_25d(g, a_blk, q=q, c=c, steps=steps, operand="A")
        b = _skew_25d(g, b_blk, q=q, c=c, steps=steps, operand="B")
        c_part = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        for t in range(steps):
            c_part = mm_acc(a, b, c_part)
            if t < steps - 1:
                a = g2.shift_row(a, -1)
                b = g2.shift_col(b, -1)
        return lax.psum(c_part, rep_axis)

    fn = spmd(body, mesh,
              in_specs=(P(row_axis, col_axis), P(row_axis, col_axis)),
              out_specs=P(row_axis, col_axis))
    return fn(A, B)


def summa_matmul_pipelined_pallas(A: jax.Array, B: jax.Array,
                                  mesh: jax.sharding.Mesh, *,
                                  interpret: bool = True) -> jax.Array:
    """Pipelined SUMMA with the accumulate-in-place Pallas MXU kernel."""
    from repro.kernels.ops import matmul_acc

    return summa_matmul_pipelined(
        A, B, mesh, local_matmul_acc=partial(matmul_acc, interpret=interpret))


def cannon_matmul_25d_pallas(A: jax.Array, B: jax.Array,
                             mesh: jax.sharding.Mesh, *,
                             interpret: bool = True) -> jax.Array:
    """2.5D Cannon with the accumulate-in-place Pallas MXU kernel."""
    from repro.kernels.ops import matmul_acc

    return cannon_matmul_25d(
        A, B, mesh, local_matmul_acc=partial(matmul_acc, interpret=interpret))
