"""FooPar Table-1 cost model + isoefficiency analysis, with TPU constants.

The paper's message-passing cost is t_c = t_s + t_w * m (start-up + per-word).
We keep the same symbolic model and instantiate (t_s, t_w) per link class:

  ICI  (intra-pod, 2D/3D torus)  ~50 GB/s per link, ~1 us hop latency
  DCI  (pod-to-pod)              ~25 GB/s effective, ~10 us latency
  HBM  (for roofline memory term) 819 GB/s per chip
  MXU  197 TFLOP/s bf16 per chip

All Table-1 costs are expressed in seconds for a message of m *bytes* over a
group of p processes.  These formulas are what ``parallel/sharding.py`` uses
to rank candidate layouts and what the §Roofline collective term is checked
against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e class, per the assignment).
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link
DCI_BW = 25e9             # bytes/s effective pod-to-pod
ICI_LATENCY = 1e-6        # t_s, seconds
DCI_LATENCY = 10e-6
HBM_PER_CHIP = 16 * 2**30  # 16 GiB (v5e)


@dataclass(frozen=True)
class LinkClass:
    t_s: float  # start-up (latency) seconds
    t_w: float  # seconds per byte

    @classmethod
    def ici(cls) -> "LinkClass":
        return cls(t_s=ICI_LATENCY, t_w=1.0 / ICI_BW)

    @classmethod
    def dci(cls) -> "LinkClass":
        return cls(t_s=DCI_LATENCY, t_w=1.0 / DCI_BW)


ICI = LinkClass.ici()
DCI = LinkClass.dci()


# ---------------------------------------------------------------------------
# Table-1 cost formulas (paper §2 and Table 1).  m in bytes, p = group size.
# ---------------------------------------------------------------------------
def t_map(t_lambda: float) -> float:
    """mapD / zipWithD: non-communicating."""
    return t_lambda


def t_reduce(m: float, p: int, link: LinkClass = ICI, t_lambda: float = 0.0) -> float:
    """reduceD: Θ(log p (t_s + t_w m + T_λ(m))) — recursive doubling."""
    if p <= 1:
        return 0.0
    return math.log2(p) * (link.t_s + link.t_w * m + t_lambda)


def t_shift(m: float, p: int, link: LinkClass = ICI) -> float:
    """shiftD: Θ(t_s + t_w m) (needs cross-section bandwidth O(p) — true on a torus)."""
    return link.t_s + link.t_w * m if p > 1 else 0.0


def t_broadcast(m: float, p: int, link: LinkClass = ICI) -> float:
    """apply(i) / one-to-all broadcast: Θ(log p (t_s + t_w m))."""
    if p <= 1:
        return 0.0
    return math.log2(p) * (link.t_s + link.t_w * m)


def t_all_gather(m: float, p: int, link: LinkClass = ICI) -> float:
    """allGatherD: Θ((t_s + t_w m)(p-1)) — ring; m is the per-process element."""
    return (link.t_s + link.t_w * m) * (p - 1) if p > 1 else 0.0


def t_all_to_all(m: float, p: int, link: LinkClass = ICI) -> float:
    """allToAllD: Θ(t_s log p + t_w m (p-1)); m is the per-destination element."""
    if p <= 1:
        return 0.0
    return link.t_s * math.log2(p) + link.t_w * m * (p - 1)


def t_all_reduce(m: float, p: int, link: LinkClass = ICI) -> float:
    """XLA all-reduce (reduce-scatter + all-gather): 2 m (p-1)/p bandwidth term."""
    if p <= 1:
        return 0.0
    return 2.0 * (link.t_s * math.log2(p) + link.t_w * m * (p - 1) / p)


def t_reduce_scatter(m: float, p: int, link: LinkClass = ICI) -> float:
    if p <= 1:
        return 0.0
    return link.t_s * math.log2(p) + link.t_w * m * (p - 1) / p


def t_reduce_scatter_ring(m: float, p: int, link: LinkClass = ICI,
                          t_lambda: float = 0.0) -> float:
    """Generic-op ring reduce-scatter (``reduce_scatter_d`` with a callable):
    p-1 nearest-neighbour steps of an m/p chunk —
    Θ((p-1)(t_s + t_w m/p + T_λ(m/p)))."""
    if p <= 1:
        return 0.0
    return (p - 1) * (link.t_s + link.t_w * m / p + t_lambda)


def t_scan(m: float, p: int, link: LinkClass = ICI, t_lambda: float = 0.0) -> float:
    """scanD (parallel prefix, Hillis-Steele recursive doubling):
    Θ(log p (t_s + t_w m + T_λ(m))) — same shape as reduceD; the prefix
    combine runs in every round."""
    if p <= 1:
        return 0.0
    return math.ceil(math.log2(p)) * (link.t_s + link.t_w * m + t_lambda)


def t_ring_shift(m: float, p: int, link: LinkClass = ICI) -> float:
    """ringShiftD: one nearest-neighbour hop — Θ(t_s + t_w m)."""
    return link.t_s + link.t_w * m if p > 1 else 0.0


# ---------------------------------------------------------------------------
# Roofline terms (per §Roofline of the experiment plan).
# ---------------------------------------------------------------------------
def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    *,
    peak_flops: float = PEAK_FLOPS_BF16,
    hbm_bw: float = HBM_BW,
    link_bw: float = ICI_BW,
) -> dict:
    """The three roofline terms, in seconds.

    ``hlo_flops``/``hlo_bytes`` are totals from ``compiled.cost_analysis()``
    (already per-program = per-device in SPMD); ``collective_bytes`` is the
    summed operand bytes of collective ops parsed from the HLO.
    """
    compute = hlo_flops / (chips * peak_flops)
    memory = hlo_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for one train step."""
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    """Decode: 2 N per token per forward."""
    return 2.0 * n_params_active * tokens


# ---------------------------------------------------------------------------
# Serving-path costs (scheduler + roofline --serve).
# ---------------------------------------------------------------------------
def decode_step_cost(n_params_active: float, batch: int, kv_bytes: float = 0.0,
                     *, chips: int = 1, bytes_per_param: int = 2,
                     overhead_s: float = 0.0,
                     peak_flops: float = PEAK_FLOPS_BF16,
                     hbm_bw: float = HBM_BW) -> dict:
    """One batched decode step: every chip streams its parameter shard once
    (plus each sequence's KV/state cache, ``kv_bytes`` per sequence) while
    doing 2·N·B flops — the classic batch-amortized memory-bound regime.
    ``overhead_s`` is a fixed per-step dispatch floor (host-driven engines).
    Returns the roofline terms plus the predicted aggregate tok/s."""
    compute = 2.0 * n_params_active * batch / (chips * peak_flops)
    memory = (n_params_active * bytes_per_param + batch * kv_bytes) / (chips * hbm_bw)
    total = max(compute, memory) + overhead_s
    return {
        "compute_s": compute,
        "memory_s": memory,
        "dominant": "compute_s" if compute >= memory else "memory_s",
        "total_s": total,
        "tok_s": batch / total if total > 0 else float("inf"),
    }


def prefill_cost(n_params_active: float, prompt_tokens: float, *,
                 chips: int = 1, bytes_per_param: int = 2,
                 peak_flops: float = PEAK_FLOPS_BF16,
                 hbm_bw: float = HBM_BW) -> dict:
    """Fused prefill of ``prompt_tokens`` (batch × prompt length) in one
    full-sequence forward: 2·N flops per token against one parameter stream —
    compute-bound for any real prompt, which is exactly why the scheduler
    prefers one fused call over a prompt-length loop of decode steps (the
    loop pays the decode memory bound ``prompt_len`` times)."""
    compute = 2.0 * n_params_active * prompt_tokens / (chips * peak_flops)
    memory = n_params_active * bytes_per_param / (chips * hbm_bw)
    total = max(compute, memory)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "dominant": "compute_s" if compute >= memory else "memory_s",
        "total_s": total,
        "tok_s": prompt_tokens / total if total > 0 else float("inf"),
    }


def paged_decode_step_cost(n_params_active: float, batch: int,
                           kv_bytes: float, *, block: int,
                           kv_token_bytes: float, chips: int = 1,
                           bytes_per_param: int = 2, overhead_s: float = 0.0,
                           table_entry_bytes: int = 4,
                           t_page_issue: float = 5e-8,
                           peak_flops: float = PEAK_FLOPS_BF16,
                           hbm_bw: float = HBM_BW) -> dict:
    """``decode_step_cost`` plus the page-table-gather term: the KV stream
    is no longer one contiguous row per sequence but ``pages`` block reads
    *through* the table, so each page costs its table entry
    (``table_entry_bytes``) on the wire plus an amortized non-contiguous
    issue latency ``t_page_issue`` (descriptor setup; pages overlap, so the
    per-page constant is small).  The term vanishes as ``block`` grows —
    ``block → seq`` recovers the dense cost, which is exactly the layout
    tradeoff: big pages gather cheap but waste pool capacity to internal
    fragmentation (``BlockPool.report``), small pages pack tight but pay
    the gather."""
    pages = max(1, -(-int(kv_bytes / kv_token_bytes) // block)) \
        if kv_token_bytes > 0 else 1
    compute = 2.0 * n_params_active * batch / (chips * peak_flops)
    gather_bytes = batch * pages * table_entry_bytes
    memory = (n_params_active * bytes_per_param + batch * kv_bytes
              + gather_bytes) / (chips * hbm_bw)
    gather = batch * pages * t_page_issue / chips
    total = max(compute, memory + gather) + overhead_s
    return {
        "compute_s": compute,
        "memory_s": memory,
        "gather_s": gather,
        "pages_per_seq": pages,
        "dominant": "compute_s" if compute >= memory + gather else "memory_s",
        "total_s": total,
        "tok_s": batch / total if total > 0 else float("inf"),
    }


def chunked_prefill_cost(n_params_active: float, prompt_tokens: float,
                         chunk: int, *, chips: int = 1,
                         bytes_per_param: int = 2,
                         kv_token_bytes: float = 0.0,
                         peak_flops: float = PEAK_FLOPS_BF16,
                         hbm_bw: float = HBM_BW) -> dict:
    """Prefill consumed in ``chunk``-token slices interleaved with decode
    ticks.  Chunking re-streams the parameters once per chunk (the fused
    call streams them once total) and re-reads the growing KV prefix each
    chunk (Θ(prompt²/2·chunk) extra KV traffic), so ``total_s`` rises as
    ``chunk`` shrinks — but ``stall_s``, the single-chunk cost and hence
    the longest any in-flight decode tick can be delayed by one admission,
    falls with it.  That stall bound is what chunked admission buys; the
    fused prefill is the ``chunk >= prompt`` corner (one "chunk", maximal
    stall)."""
    chunk = max(1, min(int(chunk), int(prompt_tokens)))
    n_chunks = -(-int(prompt_tokens) // chunk)
    compute = 2.0 * n_params_active * prompt_tokens / (chips * peak_flops)
    param_stream = n_chunks * n_params_active * bytes_per_param / (chips * hbm_bw)
    kv_restream = (prompt_tokens ** 2 / (2.0 * chunk)) * kv_token_bytes \
        / (chips * hbm_bw)
    memory = param_stream + kv_restream
    total = max(compute, memory)
    stall = max(2.0 * n_params_active * chunk / (chips * peak_flops),
                n_params_active * bytes_per_param / (chips * hbm_bw))
    return {
        "compute_s": compute,
        "memory_s": memory,
        "n_chunks": n_chunks,
        "stall_s": stall,
        "dominant": "compute_s" if compute >= memory else "memory_s",
        "total_s": total,
        "tok_s": prompt_tokens / total if total > 0 else float("inf"),
    }


# ---------------------------------------------------------------------------
# Train-step memory + time model (what ``parallel/planner.py`` scores).
# Each comm term is a Table-1 collective: the TP activation combines are
# reduceD-pairs (t_all_reduce), the ZeRO gradient scatter is the ring
# reduceScatterD (t_reduce_scatter_ring), and the FSDP/ZeRO parameter
# regather is allGatherD (t_all_gather).
# ---------------------------------------------------------------------------
def train_activation_bytes(batch_local: int, seq: int, d_model: int,
                           d_ff: int, n_layers: int, vocab: int, *,
                           remat: str = "full", act_bytes: int = 2,
                           logit_chunk: int | None = None) -> float:
    """Per-device live activation bytes of one train step.

    ``remat='full'`` keeps only the layer-boundary residual per layer (the
    layer body is recomputed in the backward); ``'dots'`` additionally keeps
    the matmul outputs; ``'none'`` keeps every intermediate (the rough
    per-token transformer constant 10·d_model + 3·d_ff).  The f32 logits
    transient rides on top (bounded by ``logit_chunk`` when set)."""
    toks = batch_local * seq
    per_tok = {"full": d_model,
               "dots": 5 * d_model + d_ff,
               "none": 10 * d_model + 3 * d_ff}[remat] * act_bytes
    logits = batch_local * (min(logit_chunk, seq) if logit_chunk else seq) * vocab * 4
    return float(toks * per_tok * n_layers + logits)


def train_memory_bytes(n_params_total: float, *, tp: int = 1,
                       fsdp_shard: int = 1, dp: int = 1,
                       grad: str = "all_reduce",
                       param_bytes: int = 4, grad_bytes: int = 2,
                       opt_state_bytes: int = 4, master: bool = False,
                       activation_bytes: float = 0.0) -> dict:
    """Per-device HBM bytes of the training state under a layout.

    Params are sharded over tp × fsdp_shard; gradients and optimizer moments
    follow the params (``all_reduce``: every device holds the full grad and
    updates its whole param residency) or the ZeRO scatter layout
    (``reduce_scatter_zero``: grads/m/v/master live on 1/dp of the non-TP
    shard — Θ(2m/p) vs the all-reduce layout's Θ(2m), ZeRO §5)."""
    shard = tp * fsdp_shard
    zero = grad == "reduce_scatter_zero"
    # the ZeRO scatter only adds sharding where FSDP storage hasn't already
    # (scatter_specs leaves FSDP-sharded leaves alone)
    gshard = tp * (fsdp_shard if fsdp_shard > 1 else (dp if zero else 1))
    params = n_params_total * param_bytes / shard
    grads = n_params_total * grad_bytes / gshard
    opt = n_params_total * (2 * opt_state_bytes + (4 if master else 0)) / gshard
    total = params + grads + opt + activation_bytes
    return {"params": params, "grads": grads, "opt": opt,
            "activations": activation_bytes, "total": total}


def train_step_cost(n_params_active: float, n_params_total: float,
                    tokens: float, *, chips: int, tp: int = 1, dp: int = 1,
                    fsdp_shard: int = 1, grad: str = "all_reduce",
                    batch_local: int = 1, seq: int = 1, d_model: int = 1,
                    n_layers: int = 1, param_bytes: int = 2,
                    grad_bytes: int = 2, opt_state_bytes: int = 4,
                    master: bool = False, remat: str = "full",
                    link: LinkClass = ICI,
                    peak_flops: float = PEAK_FLOPS_BF16,
                    hbm_bw: float = HBM_BW) -> dict:
    """Predicted wall time of one train step under a ``ParallelPlan`` layout.

    Terms (each mapped to its Table-1 collective):
      compute_s   6·N·D/(chips·peak) roofline (×4/3 under full remat — the
                  recompute is one extra forward)
      tp_comm_s   4·L per-layer activation combines over the TP group:
                  reduceD-pairs costed as ``t_all_reduce`` (XLA's RS+AG form)
      gather_s    FSDP parameter regather, fwd+bwd: ``t_all_gather`` over the
                  fsdp axes of the per-device param shard
      grad_s      the gradient reduction over the dp group —
                  all_reduce: ``t_all_reduce`` of the full (non-TP) grad;
                  reduce_scatter_zero: ring ``t_reduce_scatter_ring`` of the
                  grads + ``t_all_gather`` of the updated param shard
      update_s    optimizer HBM traffic (grad read + m/v read/write + param
                  read/write): over 1/dp of the params under ZeRO, the whole
                  residency under all_reduce
    """
    compute = 6.0 * n_params_active * tokens / (chips * peak_flops)
    if remat == "full":
        compute *= 4.0 / 3.0
    n_tp = n_params_total / tp                       # per-TP-shard params
    m_act = batch_local * seq * d_model * 2          # bf16 activations
    tp_comm = 4.0 * n_layers * t_all_reduce(m_act, tp, link)
    gather = 2.0 * t_all_gather(n_tp * param_bytes / fsdp_shard, fsdp_shard,
                                link) if fsdp_shard > 1 else 0.0
    zero = grad == "reduce_scatter_zero"
    g_bytes = n_tp * grad_bytes
    if fsdp_shard > 1:
        # FSDP storage already scatters the reduction (GSPMD folds the
        # all-reduce + slice into a reduce-scatter); the param regather is
        # gather_s above, for either grad strategy
        grad_s = t_reduce_scatter_ring(g_bytes, dp, link)
        opt_shard = fsdp_shard
    elif zero:
        grad_s = (t_reduce_scatter_ring(g_bytes, dp, link)
                  + t_all_gather(n_tp * param_bytes / max(dp, 1), dp, link))
        opt_shard = dp
    else:
        grad_s = t_all_reduce(g_bytes, dp, link)
        opt_shard = 1
    opt_traffic = n_tp * (grad_bytes + 2 * param_bytes + 4 * opt_state_bytes
                          + (8 if master else 0))
    update = opt_traffic / opt_shard / hbm_bw
    # fwd/bwd parameter streaming (3 passes over the resident shard)
    memory = 3.0 * n_tp / fsdp_shard * param_bytes / hbm_bw
    total = max(compute, memory) + tp_comm + gather + grad_s + update
    terms = {"compute_s": compute, "memory_s": memory, "tp_comm_s": tp_comm,
             "gather_s": gather, "grad_s": grad_s, "update_s": update,
             "comm_s": tp_comm + gather + grad_s, "total_s": total}
    terms["dominant"] = max(
        ("compute_s", "memory_s", "tp_comm_s", "gather_s", "grad_s",
         "update_s"), key=lambda k: terms[k])
    return terms


# ---------------------------------------------------------------------------
# Isoefficiency (paper §2, §4.2.1, §4.3): W = K * T_o(W, p).
# ---------------------------------------------------------------------------
def efficiency(t_serial: float, t_parallel: float, p: int) -> float:
    return t_serial / (p * t_parallel) if p * t_parallel > 0 else 0.0


def overhead(t_serial: float, t_parallel: float, p: int) -> float:
    """T_o(W, p) = p T_p - T_s."""
    return p * t_parallel - t_serial


def isoefficiency_matmul_generic(p: int) -> float:
    """Paper §4.2.1: W ∈ Θ(p^{5/3}) for Algorithm 1 (for-loop emulation)."""
    return p ** (5.0 / 3.0)


def isoefficiency_matmul_grid(p: int) -> float:
    """Paper §4.3 / DNS: W ∈ Θ(p log p)  (stated as Θ(n^3 + p log p))."""
    return p * math.log2(max(p, 2))


def isoefficiency_matmul_summa(p: int) -> float:
    """SUMMA on a √p×√p grid: per step, two Θ(log √p) panel broadcasts; the
    bandwidth term t_w n²/√p · log √p dominates the overhead, giving
    W ∈ Θ(p^{3/2} log p) — between DNS's Θ(p log p) (which pays p^{1/3}
    memory replication for it) and generic's Θ(p^{5/3})."""
    return p ** 1.5 * math.log2(max(p, 2))


def isoefficiency_matmul_cannon(p: int) -> float:
    """Cannon: same Θ(n²/√p) bandwidth per process but nearest-neighbour
    only (no log-factor broadcast trees): W ∈ Θ(p^{3/2})."""
    return p ** 1.5


def isoefficiency_matmul_25d(p: int, c: int = 1) -> float:
    """2.5D Cannon with c-fold replication: per-process bandwidth drops to
    Θ(n²/√(c·p)), so W ∈ Θ((p/c)^{3/2}) — c = 1 recovers Cannon's Θ(p^{3/2})
    and c = p^{1/3} reaches Θ(p), the replication-bought end of the curve
    next to DNS's Θ(p log p)."""
    return (p / c) ** 1.5


def isoefficiency_floyd_warshall(p: int) -> float:
    """Paper §5: W ∈ Θ((√p log p)^3)."""
    return (math.sqrt(p) * math.log2(max(p, 2))) ** 3


def solve_isoefficiency(t_overhead_fn, p: int, k: float = 1.0, w0: float = 1.0, iters: int = 100) -> float:
    """Numerically solve W = k * T_o(W, p) by fixed-point iteration.

    ``t_overhead_fn(W, p)`` returns the overhead for problem size W on p
    processes.  Returns the smallest W achieving the target efficiency
    implied by k (E = 1 / (1 + 1/k) in the standard formulation).
    """
    w = w0
    for _ in range(iters):
        w_new = k * t_overhead_fn(w, p)
        if w_new <= 0:
            return w
        if abs(w_new - w) / max(w, 1e-12) < 1e-9:
            return w_new
        w = 0.5 * w + 0.5 * w_new  # damped for stability
    return w


# ---------------------------------------------------------------------------
# Whole-algorithm cost predictions (used by benchmarks + sharding chooser).
# ---------------------------------------------------------------------------
def dns_matmul_cost(n: int, q: int, bytes_per_elt: int = 4, link: LinkClass = ICI,
                    peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted parallel runtime of Grid3D DNS matmul on a q^3 grid.

    T_p = 2 broadcasts (A, B along grid axes) + local multiply + reduceD over z.
    Block size (n/q)^2 elements.
    """
    blk = (n // q) ** 2
    m = blk * bytes_per_elt
    t_bcast = 2 * t_broadcast(m, q, link)
    t_mult = 2.0 * (n / q) ** 3 / peak_flops
    t_red = t_reduce(m, q, link, t_lambda=blk / peak_flops)
    return {
        "broadcast_s": t_bcast,
        "compute_s": t_mult,
        "reduce_s": t_red,
        "total_s": t_bcast + t_mult + t_red,
        "serial_s": 2.0 * n**3 / peak_flops,
        "p": q**3,
    }


def summa_matmul_cost(n: int, qx: int, qy: int | None = None,
                      bytes_per_elt: int = 4, link: LinkClass = ICI,
                      peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted runtime of SUMMA on a q_x × q_y grid (square by default).

    L = lcm(q_x, q_y) panel steps; each step row-broadcasts an
    (n/q_x × n/L) A panel over the q_y-group and column-broadcasts an
    (n/L × n/q_y) B panel over the q_x-group; local flops total 2n³/p.
    """
    qy = qy or qx
    L = math.lcm(qx, qy)
    m_a = (n // qx) * (n // L) * bytes_per_elt
    m_b = (n // L) * (n // qy) * bytes_per_elt
    t_comm = L * (t_broadcast(m_a, qy, link) + t_broadcast(m_b, qx, link))
    t_mult = 2.0 * n**3 / (qx * qy) / peak_flops
    return {
        "broadcast_s": t_comm,
        "compute_s": t_mult,
        "total_s": t_comm + t_mult,
        "serial_s": 2.0 * n**3 / peak_flops,
        "p": qx * qy,
        "mem_elts_per_proc": 3 * (n // qx) * (n // qy),
    }


def cannon_matmul_cost(n: int, qx: int, qy: int | None = None,
                       bytes_per_elt: int = 4, link: LinkClass = ICI,
                       peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted runtime of Cannon on a q_x × q_y grid: one skew ppermute
    per operand + (q_y-1) ring shifts of the A block and (q_x-1) of the B
    block — nearest-neighbour only, no broadcast trees, so the communication
    term drops the log factor of SUMMA."""
    qy = qy or qx
    m_a = (n // qx) * (n // qy) * bytes_per_elt
    m_b = m_a
    t_comm = (t_shift(m_a, qy, link) + t_shift(m_b, qx, link)
              + (qy - 1) * t_ring_shift(m_a, qy, link)
              + (qx - 1) * t_ring_shift(m_b, qx, link))
    t_mult = 2.0 * n**3 / (qx * qy) / peak_flops
    return {
        "shift_s": t_comm,
        "compute_s": t_mult,
        "total_s": t_comm + t_mult,
        "serial_s": 2.0 * n**3 / peak_flops,
        "p": qx * qy,
        "mem_elts_per_proc": 3 * (n // qx) * (n // qy),
    }


def summa_pipelined_cost(n: int, qx: int, qy: int | None = None,
                         bytes_per_elt: int = 4, link: LinkClass = ICI,
                         peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted runtime of overlap-pipelined SUMMA.

    A rotates (each rank starts on its own window — a filled ring pipeline):
    q_y - 1 block-sized nearest-neighbour hops total.  B runs one
    double-buffered ring broadcast per panel: (q_x - 1) panel-sized hops per
    step, the first of which is the pipeline-fill latency.  Every transfer
    for step t+1 is in flight during step t's multiply, so the total is
    max(t_comm, t_comp) — not their sum — plus the fill."""
    qy = qy or qx
    L = math.lcm(qx, qy)
    blk = (n // qx) * (n // qy)
    m_blk = blk * bytes_per_elt
    m_b = (n // L) * (n // qy) * bytes_per_elt
    t_comm = ((qy - 1) * t_ring_shift(m_blk, qy, link)
              + L * (qx - 1) * t_ring_shift(m_b, qx, link))
    t_comp = 2.0 * n**3 / (qx * qy) / peak_flops
    t_fill = (qx - 1) * t_ring_shift(m_b, qx, link)
    total = t_fill + max(t_comm, t_comp)
    return {
        "fill_s": t_fill,
        "comm_s": t_comm,
        "compute_s": t_comp,
        "overlap_s": t_comm + t_comp - max(t_comm, t_comp),
        "total_s": total,
        "serial_s": 2.0 * n**3 / peak_flops,
        "p": qx * qy,
        # 3 blocks + the incoming A window + 2 double-buffered B panels
        "mem_elts_per_proc": 4 * blk + 2 * (n // L) * (n // qy),
    }


def cannon_25d_cost(n: int, q: int, c: int = 1, bytes_per_elt: int = 4,
                    link: LinkClass = ICI,
                    peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted runtime of 2.5D Cannon on a q × q × c mesh (p = q²c).

    c-fold operand replication (one log-tree broadcast over the replication
    axis at load time), a skew ppermute per operand, q/c - 1 ring-shift
    steps per operand, and a final tree sum of the (n/q)² partial C over the
    c layers.  Per-process traffic interpolates Cannon (c = 1, Θ(n²/√p))
    down to the DNS-like corner (c = q, Θ(n²·c/p) plus the reduction)."""
    assert q % c == 0, (q, c)
    p = q * q * c
    blk = (n // q) ** 2
    m = blk * bytes_per_elt
    steps = q // c
    t_rep = 2 * t_broadcast(m, c, link)           # c-fold operand replication
    t_skew = 2 * t_shift(m, q, link)
    t_ring = 2 * (steps - 1) * t_ring_shift(m, q, link)
    t_red = t_reduce(m, c, link, t_lambda=blk / peak_flops)
    t_comp = 2.0 * n**3 / p / peak_flops
    comm = t_rep + t_skew + t_ring + t_red
    return {
        "replicate_s": t_rep,
        "shift_s": t_skew + t_ring,
        "reduce_s": t_red,
        "comm_s": comm,
        "compute_s": t_comp,
        "total_s": comm + t_comp,
        "serial_s": 2.0 * n**3 / peak_flops,
        "p": p,
        "c": c,
        "mem_elts_per_proc": 3 * blk,  # = 3·c·n²/p — the replication premium
    }


def floyd_warshall_cost(n: int, q: int, bytes_per_elt: int = 4, link: LinkClass = ICI,
                        peak_flops: float = PEAK_FLOPS_BF16) -> dict:
    """Predicted runtime of the 2D-grid FW (paper §5): n iterations of
    (row+col broadcast of B elements over √p) + Θ(B^2) local update."""
    b = n // q
    m = b * bytes_per_elt
    per_iter = 2 * t_broadcast(m, q, link) + (b * b) / peak_flops
    return {"total_s": n * per_iter, "per_iter_s": per_iter, "p": q * q}
