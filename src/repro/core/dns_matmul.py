"""Parallel matrix-matrix multiplication (paper §4) on the FooPar algebra.

Three implementations:

* ``generic_matmul``  — paper Algorithm 1: the q² reductions are emulated by a
  sequential Python for-loop (the paper's point: this costs Θ(p^{2/3}) nops and
  caps scalability at W ∈ Θ(p^{5/3})).
* ``dns_matmul``      — paper Algorithm 2: Grid3D abstraction; communication
  pattern of the DNS algorithm, isoefficiency Θ(n³ + p log p).
* ``dns_matmul_pallas`` — Algorithm 2 with the local block multiply done by the
  Pallas MXU kernel (the paper's JBLAS/MKL layer).

All operate on logically (n, n) matrices decomposed into q×q blocks.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .dseq import DSeq, apply_d, spmd
from .grid import Grid3D


def dns_matmul(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
               *, local_matmul: Callable | None = None,
               reduce_op: str | Callable = "sum") -> jax.Array:
    """Paper Algorithm 2::

        val GA = G mapD { case (i, j, k) => A(i)(k) }
        val GB = G mapD { case (i, j, k) => B(k)(j) }
        val C  = ((GA zipWithD GB)(_ * _) zSeq) reduceD (_ + _)

    ``mesh`` must have axes ('x', 'y', 'z') of equal size q.  The mapD lines
    are realized as shard_map in_specs: A arrives partitioned (x, z) — i.e.
    process (i, j, k) holds block A[i, k], replicated over y — and B arrives
    partitioned (z, y).  That *is* the static process↔data mapping; no data
    is moved to set it up (lazy/proxy semantics).
    """
    mm = local_matmul or (lambda a, b: a @ b)

    def body(a_blk, b_blk):
        g = Grid3D("x", "y", "z")
        c_partial = g.seq("z", a_blk).zipWithD(g.seq("z", b_blk), mm)
        # reduceD (+) along the z sequence; result replicated over z
        return c_partial.reduceD(reduce_op)

    fn = spmd(body, mesh, in_specs=(P("x", "z"), P("z", "y")), out_specs=P("x", "y"))
    return fn(A, B)


def generic_matmul(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                   axis: str = "z") -> jax.Array:
    """Paper Algorithm 1 (generic, for-loop): for every (i, j) block::

        A(i) zip Bt(j) mapD { case (a, b) => a * b } reduceD (_ + _)

    The 1-D communication group is mesh axis ``axis`` with q processes;
    process k holds A[i, k] and B[k, j] for the current (i, j).  The Python
    for-loop is the sequential ∀-emulation whose Θ(q²) nop overhead drives
    the Θ(p^{5/3}) isoefficiency of §4.2.1.
    """
    q = mesh.shape[axis]
    n = A.shape[0]
    blk = n // q
    assert n % q == 0

    def one_reduction(a_row, b_col):
        # a_row: (blk, n) sharded over axis into (blk, blk) pieces; same b_col.
        def body(a, b):
            prod = DSeq(a, axis).zipWithD(DSeq(b, axis), lambda x, y: x @ y)
            # exercise the generic tree-reduction path (user lambda _+_)
            return prod.reduceD(lambda u, v: u + v, root=None)

        return spmd(body, mesh, in_specs=(P(None, axis), P(axis, None)),
                    out_specs=P(None, None))(a_row, b_col)

    rows = []
    for i in range(q):
        cols = []
        for j in range(q):
            a_row = jax.lax.dynamic_slice_in_dim(A, i * blk, blk, 0)
            b_col = jax.lax.dynamic_slice_in_dim(B, j * blk, blk, 1)
            cols.append(one_reduction(a_row, b_col))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


def dns_matmul_pallas(A: jax.Array, B: jax.Array, mesh: jax.sharding.Mesh,
                      *, interpret: bool = True) -> jax.Array:
    """Algorithm 2 with the Pallas MXU kernel as the local multiply."""
    from repro.kernels.ops import matmul as pallas_matmul

    return dns_matmul(A, B, mesh,
                      local_matmul=partial(pallas_matmul, interpret=interpret))
