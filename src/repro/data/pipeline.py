"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step) — this is what makes
checkpoint/restart bitwise reproducible (runtime/recovery.py): after a
restart at step k the stream continues exactly where it left off, and after
an *elastic* resize the global batch content is unchanged because sharding is
derived from global indices, not host-local counters.

Per-host sharding: each process materializes only its slice of the global
batch (process_index/process_count), placed onto its addressable devices;
``jax.make_array_from_process_local_data`` assembles the global array.
Single-host (this container) degenerates to the full batch.

A background thread prefetches ``prefetch`` batches ahead.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig


@dataclass
class SyntheticTokens:
    """Markov-ish synthetic LM data: deterministic, seeded, non-trivial
    (next-token structure exists, so loss decreases measurably)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, lo: int | None = None, hi: int | None = None) -> np.ndarray:
        lo = 0 if lo is None else lo
        hi = self.global_batch if hi is None else hi
        rng = np.random.Generator(np.random.Philox(key=self.seed + (step << 20)))
        # draw per-row generators keyed by global row index => elastic-safe
        rows = []
        for r in range(lo, hi):
            rr = np.random.Generator(np.random.Philox(key=(self.seed << 1) ^ (step << 20) ^ r))
            base = rr.integers(0, self.vocab, size=self.seq_len // 2, dtype=np.int32)
            # structure: every token repeated twice (learnable bigram rule)
            row = np.repeat(base, 2)[: self.seq_len]
            noise = rr.random(self.seq_len) < 0.1
            row = np.where(noise, rr.integers(0, self.vocab, self.seq_len), row)
            rows.append(row.astype(np.int32))
        return np.stack(rows)


def make_batch_iterator(cfg: ModelConfig, shape: ShapeConfig, *,
                        seed: int = 0, start_step: int = 0,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        batch_sharding=None, prefetch: int = 2,
                        frames_dim: Optional[int] = None) -> Iterator[dict]:
    """Yields {'tokens': (B, S)} (+ 'frames' for enc-dec) global arrays."""
    ds = SyntheticTokens(cfg.vocab, shape.seq_len, shape.global_batch, seed)
    n_proc = jax.process_count()
    pidx = jax.process_index()
    per_host = shape.global_batch // n_proc
    lo, hi = pidx * per_host, (pidx + 1) * per_host

    def produce(step: int) -> dict:
        local = ds.batch_at(step, lo, hi)
        if mesh is not None and batch_sharding is not None:
            tokens = jax.make_array_from_process_local_data(batch_sharding, local)
        else:
            tokens = jnp.asarray(local)
        out = {"tokens": tokens}
        if cfg.enc_dec:
            rng = np.random.Generator(np.random.Philox(key=seed ^ (step << 21)))
            fr = rng.standard_normal((hi - lo, frames_dim or 1500, cfg.d_model),
                                     dtype=np.float32)
            out["frames"] = jnp.asarray(fr)
        return out

    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(produce(step), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
