"""Model zoo: pure-JAX modules (pytree params + init/apply functions)."""
