"""Mamba2 (SSD) blocks + the generic chunked linear-recurrence engine.

TPU adaptation: the recurrence h_i = a_i h_{i-1} + g_i k_i ⊗ v_i is computed
chunkwise (chunk L): intra-chunk contributions become dense (L×L) masked-decay
matmuls (MXU work), inter-chunk state is carried by a short ``lax.scan`` over
S/L chunks — the standard SSD reformulation, which replaces the GPU kernel's
warp-parallel scan with matmuls the MXU actually likes.  The same engine runs
mLSTM (xlstm.py) with q/k/v per head and a normalizer channel.

Decode is the O(1) recurrence step on the carried state.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import dense, dense_init, apply_norm, norm_init, _dtype, _pdtype

Params = dict


def _cstr(x, ctx, parts):
    if ctx is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*parts)))


def engine_specs(nh: int, dk: int, ctx):
    """Pick the chunk-engine sharding: heads over 'model' when divisible
    (Mamba2: 64 heads), else the q/k feature dim dk (mLSTM: 4 heads, dk 1024)
    — partial scores combine with a psum per chunk."""
    if ctx is None:
        return None, None
    if getattr(ctx, "engine_replicate", False) or \
            getattr(ctx, "dp_over_model", False):
        return None, None      # §Perf H7/C7: batch-shard only, no psums
    msz = ctx.model_size
    if nh % msz == 0:
        return ctx.model_axis, None
    if dk % msz == 0:
        return None, ctx.model_axis
    return None, None


def chunked_linear_attention(q, k, v, log_a, gate, *, chunk: int,
                             state0: Optional[jax.Array] = None,
                             unroll: int = 1, ctx=None,
                             h_shard=None, dk_shard=None, mm_bf16: bool = False
                             ) -> Tuple[jax.Array, jax.Array]:
    """y[i] = Σ_{j≤i} exp(cum_i − cum_j) · gate_j · (q_i·k_j) · v_j  (+ carry).

    q, k: (B, S, H, dk); v: (B, S, H, dv); log_a, gate: (B, S, H).
    Returns (y (B, S, H, dv), final_state (B, H, dk, dv)).
    All statistics in f32; the L×L intra-chunk matmuls in input dtype.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0
    n_chunks = s // L
    f32 = jnp.float32

    qc = q.reshape(b, n_chunks, L, h, dk)
    kc = k.reshape(b, n_chunks, L, h, dk)
    vc = v.reshape(b, n_chunks, L, h, dv)
    lac = log_a.reshape(b, n_chunks, L, h).astype(f32)
    gc = gate.reshape(b, n_chunks, L, h).astype(f32)

    B = ctx.batch_axes if (ctx and ctx.batch_axes) else None
    qc = _cstr(qc, ctx, (B, None, None, h_shard, dk_shard))
    kc = _cstr(kc, ctx, (B, None, None, h_shard, dk_shard))
    vc = _cstr(vc, ctx, (B, None, None, h_shard, None))
    lac = _cstr(lac, ctx, (B, None, None, h_shard))
    gc = _cstr(gc, ctx, (B, None, None, h_shard))

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), f32)
    state0 = _cstr(state0, ctx, (B, h_shard, dk_shard, None))

    mm = jnp.bfloat16 if mm_bf16 else f32  # §Perf H8: MXU dtype for matmuls

    def step(state, xs):
        qq, kk, vv, la, g = xs          # (b, L, h, ...)
        cum = jnp.cumsum(la, axis=1)    # (b, L, h) inclusive (f32 stats)
        # intra-chunk: M[b,h,i,j] = (q_i·k_j) exp(cum_i - cum_j) g_j, j<=i
        scores = jnp.einsum("bihd,bjhd->bhij", qq.astype(mm), kk.astype(mm),
                            preferred_element_type=f32)
        decay = cum[:, :, None, :] - cum[:, None, :, :]       # (b, i, j, h)
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        decay = jnp.where(mask, decay, -jnp.inf)              # mask BEFORE exp
        m = scores * jnp.exp(decay).transpose(0, 3, 1, 2) * g.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhv->bihv", m.astype(mm), vv.astype(mm),
                             preferred_element_type=f32)
        # inter-chunk: y_inter[i] = exp(cum_i) q_i · S_prev
        y_inter = jnp.einsum("bihd,bhdv->bihv", qq.astype(f32), state) \
            * jnp.exp(cum)[..., None]
        # state update: S = exp(cum_L) S + Σ_j exp(cum_L - cum_j) g_j k_j ⊗ v_j
        last = cum[:, -1:, :]                                  # (b, 1, h)
        w = jnp.exp(last - cum) * g                            # (b, L, h)
        s_new = state * jnp.exp(last[:, 0])[:, :, None, None]
        s_new = s_new + jnp.einsum("bjhd,bjhv->bhdv",
                                   (kk.astype(f32) * w[..., None]).astype(mm),
                                   vv.astype(mm), preferred_element_type=f32)
        return s_new, y_intra + y_inter

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, lac, gc))
    state, ys = lax.scan(step, state0, xs, unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dv)
    return y.astype(v.dtype), state


def linear_attention_step(state, q, k, v, log_a, gate):
    """One decode step.  state: (B, H, dk, dv); q,k: (B,H,dk); v: (B,H,dv);
    log_a, gate: (B,H).  Returns (y (B,H,dv), new_state)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[:, :, None, None]
    upd = jnp.einsum("bhd,bhv->bhdv", k.astype(f32) * gate.astype(f32)[..., None],
                     v.astype(f32))
    state = state * a + upd
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_init(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    ks = jax.random.split(rng, 4)
    conv_ch = d_in + 2 * s.d_state
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * s.d_state + nh, cfg),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_ch), _pdtype(cfg))
        / math.sqrt(s.conv_width),
        "A_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus(-2) ≈ 0.13
        "norm": norm_init(d_in, cfg),
        "out_proj": dense_init(ks[2], d_in, d, cfg),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 cache: Optional[jax.Array] = None) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv.  x: (B, S, C); w: (W, C).
    With ``cache`` (the last W-1 inputs) the window is seeded from it instead
    of zero padding and the rolled last-(W-1)-inputs cache is returned —
    S == 1 is the decode step, S > 1 the fused prefill."""
    wlen = w.shape[0]
    prev = cache if cache is not None else \
        jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B, W-1+S, C)
    # (B, S, W, C) windows via stacked slices (W is tiny, e.g. 4)
    y = sum(xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
            for i in range(wlen))
    return y.astype(x.dtype), (xp[:, x.shape[1]:, :] if cache is not None else None)


def mamba2_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                 cache: Optional[dict] = None, ctx=None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d) → (B, S, d).  cache (decode): {'conv': (B,W-1,C), 'ssm': (B,H,dk,dv)}."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    b, seq, _ = x.shape

    zxbcdt = dense(x, p["in_proj"], cfg)
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * s.d_state], axis=-1)

    new_cache = {}
    conv_cache = cache.get("conv") if cache is not None else None
    xbc, conv_new = _causal_conv(xbc, p["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(xbc.dtype)
    if cache is not None:
        new_cache["conv"] = conv_new

    xh = xbc[..., :d_in].reshape(b, seq, nh, s.head_dim)
    bmat = xbc[..., d_in:d_in + s.d_state]                   # (B,S,dk) shared heads
    cmat = xbc[..., d_in + s.d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    log_a = -jnp.exp(p["A_log"]) * dt                                 # (B,S,nh)

    q = jnp.broadcast_to(cmat[:, :, None, :], (b, seq, nh, s.d_state))
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, seq, nh, s.d_state))

    if cache is not None and seq == 1:
        y, ssm_new = linear_attention_step(cache["ssm"], q[:, 0], k[:, 0],
                                           xh[:, 0], log_a[:, 0], dt[:, 0])
        y = y[:, None]
        new_cache["ssm"] = ssm_new
    else:
        hs_, dks_ = engine_specs(nh, s.d_state, ctx)
        # fused prefill seeds the chunk scan from the cached state and keeps
        # the final state (train/eval forward discards it)
        y, ssm_state = chunked_linear_attention(
            q, k, xh, log_a, dt, chunk=s.chunk,
            state0=cache["ssm"] if cache is not None else None,
            unroll=s.unroll, ctx=ctx, h_shard=hs_, dk_shard=dks_,
            mm_bf16=s.mm_bf16)
        if cache is not None:
            new_cache["ssm"] = ssm_state

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, seq, d_in).astype(_dtype(cfg))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = apply_norm(p["norm"], y, cfg)
    out = dense(y, p["out_proj"], cfg)
    return out, (new_cache if cache is not None else None)
