"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar memory).

mLSTM is run through the same chunked linear-recurrence engine as Mamba2
(ssm.py) — it is exponential-gated linear attention with a normalizer
channel: state S = Σ_j (Π f) i_j k_j ⊗ [v_j, 1], output
h = (q·S)[:dv] / max(|q·S|[dv], 1).  Gating simplified to sigmoid i/f gates
(log-sigmoid decays), which keeps the recurrence stable without the paper's
m-stabilizer; noted in DESIGN.md §7.

sLSTM keeps per-channel scalar state with exponential gating + stabilizer and
runs as a true sequential ``lax.scan`` over time (the paper's inherently
sequential part; cheap — elementwise per step).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import dense, dense_init, apply_norm, norm_init, _dtype, _pdtype
from repro.models.ssm import (chunked_linear_attention, linear_attention_step,
                              engine_specs)

Params = dict


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    d_in = int(cfg.xlstm.proj_factor * d)
    nh = cfg.n_heads
    hd = d_in // nh
    return d, d_in, nh, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(rng, cfg: ModelConfig) -> Params:
    d, d_in, nh, hd = _dims(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_in, cfg),     # x and gate z
        "wq": dense_init(ks[1], d_in, d_in, cfg),
        "wk": dense_init(ks[2], d_in, d_in, cfg),
        "wv": dense_init(ks[3], d_in, d_in, cfg),
        "w_gates": dense_init(ks[4], d_in, 2 * nh, cfg),    # i, f per head
        "norm": norm_init(d_in, cfg),
        "down_proj": dense_init(ks[5], d_in, d, cfg),
    }


def mlstm_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[dict] = None, ctx=None) -> Tuple[jax.Array, Optional[dict]]:
    d, d_in, nh, hd = _dims(cfg)
    b, s, _ = x.shape
    up = dense(x, p["up_proj"], cfg)
    xi, z = jnp.split(up, 2, axis=-1)

    q = dense(xi, p["wq"], cfg).reshape(b, s, nh, hd) / math.sqrt(hd)
    k = dense(xi, p["wk"], cfg).reshape(b, s, nh, hd)
    v = dense(xi, p["wv"], cfg).reshape(b, s, nh, hd)
    gates = dense(xi, p["w_gates"], cfg).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)              # (B,S,nh)
    log_f = jax.nn.log_sigmoid(f_raw)
    i_g = jax.nn.sigmoid(i_raw)

    # normalizer channel: v' = [v, 1]
    v_ext = jnp.concatenate([v, jnp.ones((b, s, nh, 1), v.dtype)], axis=-1)

    if cache is not None and s == 1:
        y, state = linear_attention_step(cache["ssm"], q[:, 0], k[:, 0],
                                         v_ext[:, 0], log_f[:, 0], i_g[:, 0])
        y = y[:, None]
        new_cache = {"ssm": state}
    else:
        hs_, dks_ = engine_specs(nh, hd, ctx)
        # fused prefill seeds the chunk scan from the cached state and keeps
        # the final state (train/eval forward discards it)
        y, state = chunked_linear_attention(
            q, k, v_ext, log_f, i_g, chunk=cfg.xlstm.chunk,
            state0=cache["ssm"] if cache is not None else None,
            unroll=cfg.xlstm.unroll, ctx=ctx, h_shard=hs_, dk_shard=dks_,
            mm_bf16=cfg.xlstm.mm_bf16)
        new_cache = {"ssm": state} if cache is not None else None

    num, den = y[..., :hd], y[..., hd:]
    h = num.astype(jnp.float32) / jnp.maximum(jnp.abs(den.astype(jnp.float32)), 1.0)
    h = h.reshape(b, s, d_in).astype(_dtype(cfg))
    h = apply_norm(p["norm"], h, cfg)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    return dense(h, p["down_proj"], cfg), new_cache


# ---------------------------------------------------------------------------
# sLSTM (sequential, exponential gating with stabilizer)
# ---------------------------------------------------------------------------
def slstm_init(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, cfg),            # z, i, f, o pre-acts
        "norm": norm_init(d, cfg),
        "proj": dense_init(ks[1], d, d, cfg),
    }


def slstm_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    d = cfg.d_model
    b, s, _ = x.shape
    pre = dense(x, p["w_in"], cfg).astype(jnp.float32)
    z, i_raw, f_raw, o_raw = jnp.split(pre, 4, axis=-1)      # (B,S,d) each
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)

    def step(carry, xs):
        c, n, m = carry
        zt, it, ft, ot = xs
        m_new = jnp.maximum(ft + m, it)
        c = jnp.exp(ft + m - m_new) * c + jnp.exp(it - m_new) * zt
        n = jnp.exp(ft + m - m_new) * n + jnp.exp(it - m_new)
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new), h

    if cache is not None and s == 1:
        carry = (cache["c"], cache["n"], cache["m"])
        carry, h = step(carry, (z[:, 0], i_raw[:, 0], f_raw[:, 0], o[:, 0]))
        h = h[:, None]
        new_cache = {"c": carry[0], "n": carry[1], "m": carry[2]}
    else:
        init = (cache["c"], cache["n"], cache["m"]) if cache is not None else \
            tuple(jnp.zeros((b, d), jnp.float32) for _ in range(2)) + \
            (jnp.full((b, d), -1e30, jnp.float32),)
        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (z, i_raw, f_raw, o))
        carry, hs = lax.scan(step, init, xs)
        h = jnp.moveaxis(hs, 0, 1)
        new_cache = {"c": carry[0], "n": carry[1], "m": carry[2]} \
            if cache is not None else None

    h = apply_norm(p["norm"], h.astype(_dtype(cfg)), cfg)
    return dense(h, p["proj"], cfg), new_cache


def slstm_init_cache(b: int, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {"c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.zeros((b, d), jnp.float32),
            "m": jnp.full((b, d), -1e30, jnp.float32)}
