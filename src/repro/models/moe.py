"""Mixture-of-Experts layer with expert parallelism on the FooPar algebra.

Design (DESIGN.md §3): after the attention block the activations are
replicated over the ``model`` axis (row-parallel all-reduce output), so every
(data-shard, model-shard) device already holds its batch shard's tokens.
Expert parallelism therefore needs **no all-to-all dispatch**: device
(d, m) locally selects the assignments of its tokens to *its* experts
(``mapD``), computes them with ``jax.lax.ragged_dot`` (sorted, grouped), and
the per-shard partial outputs are combined with one ``reduceD('sum')`` over
``model`` — the same single all-reduce a dense row-parallel FFN costs.
Table-1 cost: Θ(log p (t_s + t_w·T·d)) — vs an a2a dispatch+return
Θ(2 t_w·T·k/ep·d); the a2a variant is a §Perf hillclimb candidate.

Two layouts, auto-selected:
  * ``ep``: experts sharded over ``model`` (needs n_experts % ep == 0);
    capacity-dropped selection per shard (Kimi-K2: 384/16 = 24 per shard).
  * ``tp``: expert count < mesh axis (Mixtral: 8 < 16) — every shard computes
    all experts on a 1/ep slice of d_ff (dropless), same final psum.

The layer is a *full-manual* ``shard_map`` over every mesh axis: token
selection (sort/gather) stays device-local by construction, exactly the
paper's static process↔data mapping discipline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core.compat import shard_map as _shard_map
from repro.models.layers import dense_init, _dtype, _pdtype

Params = dict


@dataclass(frozen=True)
class MeshCtx:
    """Where a model call runs: mesh + role of each axis."""
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)   # axes params are sharded over
    moe_a2a_ep: bool = False                 # token-routing EP (§Perf H6)
    engine_replicate: bool = False           # SSM/mLSTM engine batch-shard only
    seq_parallel: bool = False               # S-sharded residual (§Perf H5)
    foopar_tp: bool = False                  # algebra (DSeq) TP matmuls in MLP
    manual_attention: bool = False           # manual shard_map SDPA (§Perf A8)
    dp_over_model: bool = False              # pure DP over both axes (§Perf C7)

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]


def moe_init(rng, cfg: ModelConfig) -> Params:
    e = cfg.moe
    d, ff = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(rng, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, e.n_experts), jnp.float32) * scale),
        "w_gate": jax.random.normal(ks[1], (e.n_experts, d, ff), _pdtype(cfg)) * scale,
        "w_up": jax.random.normal(ks[2], (e.n_experts, d, ff), _pdtype(cfg)) * scale,
        "w_down": jax.random.normal(ks[3], (e.n_experts, ff, d), _pdtype(cfg)) / math.sqrt(ff),
    }
    if e.n_shared_experts:
        sff = ff * e.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], d, sff, cfg),
            "w_up": dense_init(kk[1], d, sff, cfg),
            "w_down": dense_init(kk[2], sff, d, cfg),
        }
    return p


def _route(x_flat: jax.Array, router_w: jax.Array, top_k: int):
    """Top-k routing with softmax-renormalized weights (f32)."""
    logits = jnp.matmul(x_flat.astype(jnp.float32), router_w,
                        preferred_element_type=jnp.float32)
    top_v, top_i = lax.top_k(logits, top_k)                 # (T, k)
    weights = jax.nn.softmax(top_v, axis=-1)                # (T, k)
    # aux load-balancing stats (Switch-style), returned for the loss
    probs = jax.nn.softmax(logits, axis=-1)
    return top_i, weights, probs


def _expert_ffn(xs: jax.Array, group_sizes: jax.Array, w_gate, w_up, w_down, dtype):
    """Grouped SwiGLU via ragged_dot.  xs: (C, d) sorted by group."""
    xs = xs.astype(dtype)
    g = lax.ragged_dot(xs, w_gate.astype(dtype), group_sizes)
    u = lax.ragged_dot(xs, w_up.astype(dtype), group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u)
    return lax.ragged_dot(h, w_down.astype(dtype), group_sizes)


def _moe_body_ep(x, router_w, w_gate, w_up, w_down, shared, *, cfg: ModelConfig,
                 ep: int, my_shard, fsdp_axes: Tuple[str, ...],
                 model_axis: Optional[str]):
    """Per-device body, expert-sharded layout.  x: (B_loc, S, d) replicated
    over model; expert weights: (E/ep, d[, /fsdp], ff) local shards."""
    e = cfg.moe
    dtype = _dtype(cfg)
    b, s, d = x.shape
    t = b * s
    e_local = e.n_experts // ep
    x_flat = x.reshape(t, d)

    # FSDP: gather the d-sharded expert weights (Table-1 allGatherD)
    for ax in fsdp_axes:
        w_gate = lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = lax.all_gather(w_down, ax, axis=2, tiled=True)

    top_i, weights, probs = _route(x_flat, router_w, e.top_k)

    # --- local selection: my tokens' assignments to my experts -------------
    cap = int(math.ceil(t * e.top_k / ep * e.capacity_factor))
    cap = max(8, min(cap, t * e.top_k))
    flat_e = top_i.reshape(-1)                               # (T*k,)
    flat_w = weights.reshape(-1)
    is_mine = (flat_e // e_local) == my_shard
    big = t * e.top_k + 1
    pri = jnp.where(is_mine, jnp.arange(t * e.top_k), big)
    order = jnp.argsort(pri)[:cap]                           # first-come capacity
    valid = pri[order] < big
    tok = order // e.top_k
    eid = jnp.where(valid, flat_e[order] - my_shard * e_local, e_local)
    wsel = jnp.where(valid, flat_w[order], 0.0)

    # group by local expert id (stable sort keeps token order within expert)
    g_order = jnp.argsort(eid, stable=True)
    eid_s = eid[g_order]
    tok_s = tok[g_order]
    w_s = wsel[g_order]
    group_sizes = jnp.bincount(eid_s, length=e_local).astype(jnp.int32)

    xs = x_flat[tok_s]                                       # (C, d) gather
    ys = _expert_ffn(xs, group_sizes, w_gate, w_up, w_down, dtype)  # (C, d)
    out = jnp.zeros((t + 1, d), jnp.float32).at[
        jnp.where(eid_s < e_local, tok_s, t)].add(ys.astype(jnp.float32) * w_s[:, None])
    out = out[:t]

    if shared is not None:
        out = out + _shared_ffn(x_flat, shared, cfg, model_axis=None)  # partial added pre-psum
    if model_axis is not None:
        out = lax.psum(out, model_axis)                      # reduceD('sum')
    return out.reshape(b, s, d).astype(dtype), probs


def _shared_ffn(x_flat, shared, cfg, model_axis):
    """Shared expert: dense SwiGLU, ff sharded over model (col→row parallel);
    returns the *partial* (pre-psum) output so it folds into the expert psum."""
    dtype = _dtype(cfg)
    g = jnp.matmul(x_flat.astype(dtype), shared["w_gate"].astype(dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.matmul(x_flat.astype(dtype), shared["w_up"].astype(dtype),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(dtype)
    return jnp.matmul(h, shared["w_down"].astype(dtype),
                      preferred_element_type=jnp.float32)


def _moe_body_tp(x, router_w, w_gate, w_up, w_down, shared, *, cfg: ModelConfig,
                 fsdp_axes: Tuple[str, ...], model_axis: Optional[str]):
    """ff-sharded layout (expert count < axis size, e.g. Mixtral): every shard
    computes ALL assignments (dropless) on a d_ff/ep slice."""
    e = cfg.moe
    dtype = _dtype(cfg)
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)

    for ax in fsdp_axes:
        w_gate = lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = lax.all_gather(w_down, ax, axis=2, tiled=True)

    top_i, weights, probs = _route(x_flat, router_w, e.top_k)
    flat_e = top_i.reshape(-1)
    flat_w = weights.reshape(-1)
    tok = jnp.arange(t * e.top_k) // e.top_k

    g_order = jnp.argsort(flat_e, stable=True)
    eid_s = flat_e[g_order]
    tok_s = tok[g_order]
    w_s = flat_w[g_order]
    group_sizes = jnp.bincount(eid_s, length=e.n_experts).astype(jnp.int32)

    xs = x_flat[tok_s]
    ys = _expert_ffn(xs, group_sizes, w_gate, w_up, w_down, dtype)
    out = jnp.zeros((t, d), jnp.float32).at[tok_s].add(
        ys.astype(jnp.float32) * w_s[:, None])

    if shared is not None:
        out = out + _shared_ffn(x_flat, shared, cfg, model_axis=None)
    if model_axis is not None:
        out = lax.psum(out, model_axis)
    return out.reshape(b, s, d).astype(dtype), probs


def _moe_body_a2a(x, router_w, w_gate, w_up, w_down, shared, *,
                  cfg: ModelConfig, data_axis: str, model_axis: str,
                  dp: int, my_data_shard):
    """Token-routing EP (FooPar ``allToAllD``): experts are *resident*,
    sharded (E/dp over ``data``) × (ff/tp over ``model``); tokens travel to
    their expert's data-shard via all_to_all, compute with ragged_dot on the
    ff slice, psum the down-projection over ``model``, and a2a back.

    Wire per step ≈ 2·T·k·d·bytes  (tokens move, ~MBs) instead of the
    weight-gathering layout's ≈ E·d·ff·bytes (TBs for 1T-param MoE) — the
    §Perf kimi-decode hillclimb."""
    e = cfg.moe
    dtype = _dtype(cfg)
    b, s, d = x.shape
    t = b * s
    e_local = e.n_experts // dp
    x_flat = x.reshape(t, d)

    top_i, weights, probs = _route(x_flat, router_w, e.top_k)
    flat_e = top_i.reshape(-1)                                  # (T*k,)
    flat_w = weights.reshape(-1)
    dest = flat_e // e_local                                    # data shard
    tok = jnp.arange(t * e.top_k) // e.top_k

    # per-destination send buckets (capacity per dest)
    cap = max(8, int(math.ceil(t * e.top_k / dp * e.capacity_factor)))
    order = jnp.argsort(dest * (t * e.top_k) + jnp.arange(t * e.top_k))
    # rank within destination
    big = dp
    onehot_pos = jnp.cumsum(jax.nn.one_hot(dest, dp, dtype=jnp.int32), axis=0)
    slot = onehot_pos[jnp.arange(t * e.top_k), dest] - 1        # 0-based
    valid = slot < cap
    send_x = jnp.zeros((dp, cap, d), dtype)
    send_meta = jnp.full((dp, cap, 3), -1.0, jnp.float32)       # tok, eid, w
    idx = (dest, jnp.where(valid, slot, cap - 1))
    send_x = send_x.at[idx[0], idx[1]].set(
        jnp.where(valid[:, None], x_flat[tok].astype(dtype), send_x[idx[0], idx[1]]))
    send_meta = send_meta.at[idx[0], idx[1]].set(
        jnp.where(valid[:, None],
                  jnp.stack([tok.astype(jnp.float32),
                             (flat_e % e_local).astype(jnp.float32),
                             flat_w], axis=-1),
                  send_meta[idx[0], idx[1]]))

    rx = lax.all_to_all(send_x, data_axis, 0, 0, tiled=True)     # (dp*cap, d)
    rmeta = lax.all_to_all(send_meta, data_axis, 0, 0, tiled=True)
    rx = rx.reshape(dp * cap, d)
    rmeta = rmeta.reshape(dp * cap, 3)
    reid = rmeta[:, 1].astype(jnp.int32)
    rvalid = rmeta[:, 0] >= 0
    reid = jnp.where(rvalid, reid, e_local)

    g_order = jnp.argsort(reid, stable=True)
    xs = rx[g_order]
    group_sizes = jnp.bincount(jnp.where(rvalid, rmeta[:, 1].astype(jnp.int32),
                                         e_local), length=e_local).astype(jnp.int32)
    ys = _expert_ffn(xs, group_sizes, w_gate, w_up, w_down, dtype)  # ff-slice partial
    ys = lax.psum(ys.astype(jnp.float32), model_axis)            # (dp*cap, d)
    # unsort, a2a back to origin shards
    inv = jnp.argsort(g_order)
    back = lax.all_to_all(ys[inv].reshape(dp, cap, d), data_axis, 0, 0,
                          tiled=True).reshape(dp * cap, d)
    bmeta = lax.all_to_all(rmeta.reshape(dp, cap, 3), data_axis, 0, 0,
                           tiled=True).reshape(dp * cap, 3)
    btok = bmeta[:, 0].astype(jnp.int32)
    bw = jnp.where(bmeta[:, 0] >= 0, bmeta[:, 2], 0.0)
    out = jnp.zeros((t + 1, d), jnp.float32).at[
        jnp.where(bmeta[:, 0] >= 0, btok, t)].add(back * bw[:, None])
    out = out[:t]

    if shared is not None:
        out_sh = _shared_ffn(x_flat, shared, cfg, model_axis=None)
        out = out + lax.psum(out_sh, model_axis)
    return out.reshape(b, s, d).astype(dtype), probs


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            ctx: Optional[MeshCtx], *, a2a_ep: Optional[bool] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN.  Returns (output, router_probs_for_aux_loss)."""
    e = cfg.moe
    shared = p.get("shared")
    if a2a_ep is None:
        a2a_ep = bool(ctx and ctx.moe_a2a_ep)

    if ctx is None:
        # single-device path (smoke tests): same body, group of 1
        body = partial(_moe_body_tp, cfg=cfg, fsdp_axes=(), model_axis=None)
        out, probs = body(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
        return out, probs

    ep = ctx.model_size
    fsdp = tuple(a for a in ctx.fsdp_axes if a != ctx.model_axis)
    use_ep = e.n_experts % ep == 0 and e.n_experts >= ep
    bspec = P(ctx.batch_axes, None, None)

    if a2a_ep and "data" in ctx.batch_axes:
        dp = ctx.mesh.shape["data"]
        assert e.n_experts % dp == 0, (e.n_experts, dp)
        espec_in = P("data", None, ctx.model_axis)      # (E/dp, d, ff/tp)
        espec_out = P("data", ctx.model_axis, None)     # (E/dp, ff/tp, d)
        shared_specs = None
        if shared is not None:
            shared_specs = {"w_gate": P(None, ctx.model_axis),
                            "w_up": P(None, ctx.model_axis),
                            "w_down": P(ctx.model_axis, None)}

        def body(xl, rw, wg, wu, wd, sh):
            return _moe_body_a2a(xl, rw, wg, wu, wd, sh, cfg=cfg,
                                 data_axis="data", model_axis=ctx.model_axis,
                                 dp=dp, my_data_shard=lax.axis_index("data"))

        fn = _shard_map(
            body, mesh=ctx.mesh,
            in_specs=(bspec, P(None, None), espec_in, espec_in, espec_out,
                      shared_specs),
            out_specs=(bspec, P(ctx.batch_axes, None)),
            check=False,
        )
        return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)

    if use_ep:
        espec_in = P(ctx.model_axis, fsdp if fsdp else None, None)    # (E, d, ff)
        espec_out = P(ctx.model_axis, None, fsdp if fsdp else None)   # (E, ff, d)

        def body(xl, rw, wg, wu, wd, sh):
            return _moe_body_ep(xl, rw, wg, wu, wd, sh, cfg=cfg, ep=ep,
                                my_shard=lax.axis_index(ctx.model_axis),
                                fsdp_axes=fsdp, model_axis=ctx.model_axis)
    else:
        espec_in = P(None, fsdp if fsdp else None, ctx.model_axis)
        espec_out = P(None, ctx.model_axis, fsdp if fsdp else None)

        def body(xl, rw, wg, wu, wd, sh):
            return _moe_body_tp(xl, rw, wg, wu, wd, sh, cfg=cfg,
                                fsdp_axes=fsdp, model_axis=ctx.model_axis)

    shared_specs = None
    if shared is not None:
        shared_specs = {
            "w_gate": P(None, ctx.model_axis),
            "w_up": P(None, ctx.model_axis),
            "w_down": P(ctx.model_axis, None),
        }

    fn = _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(bspec, P(None, None), espec_in, espec_in, espec_out, shared_specs),
        out_specs=(bspec, P(ctx.batch_axes, None)),
        check=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)


def load_balance_loss(probs: jax.Array, top_i_onehot_mean: Optional[jax.Array] = None) -> jax.Array:
    """Switch-transformer aux loss surrogate: E * mean_e(fraction) * mean_e(prob).
    With only router probs available we use the prob-entropy surrogate."""
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))  # (E,)
    return probs.shape[-1] * jnp.sum(me * me)
