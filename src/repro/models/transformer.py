"""Decoder-only LM assembly: dense / MoE / hybrid(Mamba2) / xLSTM families.

Layers are grouped into the config's ``block_pattern`` period and scanned
(``lax.scan``) over ``n_periods`` stacked parameter pytrees — this keeps the
HLO size O(period) instead of O(n_layers), which matters both for compile
time and for remat policy application (one ``jax.checkpoint`` per period).

Decode threads a per-layer cache pytree through the same scan (cache as scan
xs, updated cache as scan ys).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X

Params = dict


# ---------------------------------------------------------------------------
# Per-kind init / apply
# ---------------------------------------------------------------------------
def _block_init(kind: str, rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 4)
    if kind == "attn":
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "attn": L.attention_init(ks[0], cfg),
                "ln2": L.norm_init(cfg.d_model, cfg),
                "mlp": L.mlp_init(ks[1], cfg)}
    if kind == "attn_moe":
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "attn": L.attention_init(ks[0], cfg),
                "ln2": L.norm_init(cfg.d_model, cfg),
                "moe": M.moe_init(ks[1], cfg)}
    if kind in ("mamba2", "mamba2_attn"):
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "mamba": S.mamba2_init(ks[0], cfg)}
    if kind == "mlstm":
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "mlstm": X.mlstm_init(ks[0], cfg)}
    if kind == "slstm":
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "slstm": X.slstm_init(ks[0], cfg)}
    raise ValueError(kind)


def _block_apply(kind: str, p: Params, h: jax.Array, positions, cfg: ModelConfig,
                 ctx, cache: Optional[dict], cache_pos,
                 shared_attn: Optional[Params],
                 block_tables=None) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (h, new_cache, aux_loss_contribution).  ``block_tables``
    switches the attention cache to the paged page-arena view (pure
    attention patterns only — ``supports_paged``)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Any = None

    if kind == "attn" or kind == "attn_moe":
        a_cache = cache.get("attn") if cache else None
        x1 = L.apply_norm(p["ln1"], h, cfg)
        attn_out, a_new = L.attention(p["attn"], x1, positions, cfg,
                                      cache=a_cache, cache_pos=cache_pos,
                                      block_tables=block_tables, ctx=ctx)
        if cfg.parallel_block:
            # command-r style: attn ∥ mlp read the same normed input
            if kind == "attn":
                ffn_out = L.mlp(p["mlp"], x1, cfg, ctx=ctx)
            else:
                ffn_out, probs = M.moe_ffn(p["moe"], x1, cfg, ctx)
                aux = aux + M.load_balance_loss(probs)
            h = h + attn_out + ffn_out
        else:
            h = h + attn_out
            x2 = L.apply_norm(p["ln2"], h, cfg)
            if kind == "attn":
                h = h + L.mlp(p["mlp"], x2, cfg, ctx=ctx)
            else:
                moe_out, probs = M.moe_ffn(p["moe"], x2, cfg, ctx)
                aux = aux + M.load_balance_loss(probs)
                h = h + moe_out
        new_cache = {"attn": a_new} if cache is not None else None

    elif kind in ("mamba2", "mamba2_attn"):
        m_cache = cache.get("mamba") if cache else None
        out, m_new = S.mamba2_block(p["mamba"], L.apply_norm(p["ln1"], h, cfg),
                                    cfg, cache=m_cache, ctx=ctx)
        h = h + out
        new_cache = {"mamba": m_new} if cache is not None else None
        if kind == "mamba2_attn":
            assert shared_attn is not None
            sa_cache = cache.get("shared_attn") if cache else None
            a_out, sa_new = L.attention(shared_attn["attn"],
                                        L.apply_norm(shared_attn["ln1"], h, cfg),
                                        positions, cfg, cache=sa_cache,
                                        cache_pos=cache_pos, ctx=ctx)
            h = h + a_out
            h = h + L.mlp(shared_attn["mlp"], L.apply_norm(shared_attn["ln2"], h, cfg), cfg, ctx=ctx)
            if cache is not None:
                new_cache["shared_attn"] = sa_new

    elif kind == "mlstm":
        m_cache = cache.get("mlstm") if cache else None
        out, m_new = X.mlstm_block(p["mlstm"], L.apply_norm(p["ln1"], h, cfg),
                                   cfg, cache=m_cache, ctx=ctx)
        h = h + out
        new_cache = {"mlstm": m_new} if cache is not None else None

    elif kind == "slstm":
        s_cache = cache.get("slstm") if cache else None
        out, s_new = X.slstm_block(p["slstm"], L.apply_norm(p["ln1"], h, cfg),
                                   cfg, cache=s_cache)
        h = h + out
        new_cache = {"slstm": s_new} if cache is not None else None
    else:
        raise ValueError(kind)

    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------
def init(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 4)
    period = cfg.block_pattern

    def one_period(prng):
        kr = jax.random.split(prng, len(period))
        return tuple(_block_init(k, kr[i], cfg) for i, k in enumerate(period))

    period_rngs = jax.random.split(ks[0], cfg.n_periods)
    # stack params over periods (leading axis = n_periods)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[one_period(r) for r in period_rngs]) \
        if cfg.n_periods > 1 else jax.tree.map(lambda x: x[None], one_period(period_rngs[0]))

    params: Params = {
        "embed": L.embed_init(ks[1], cfg),
        "layers": stacked,
        "final_norm": L.norm_init(cfg.d_model, cfg),
    }
    if "mamba2_attn" in period:
        params["shared_attn"] = {
            "ln1": L.norm_init(cfg.d_model, cfg),
            "attn": L.attention_init(ks[2], cfg),
            "ln2": L.norm_init(cfg.d_model, cfg),
            "mlp": L.mlp_init(ks[3], cfg),
        }
    return params


def init_abstract(cfg: ModelConfig) -> Params:
    """Shape-only init (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
            ctx=None, remat: str = "none", unroll: int = 1,
            embeddings: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32 (or precomputed ``embeddings`` (B, S, d) for
    stub-frontend modalities).  Returns (logits_f32 (B, S, V), aux_loss)."""
    period = cfg.block_pattern
    h = embeddings if embeddings is not None else L.embed(params["embed"], tokens, cfg)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    shared_attn = params.get("shared_attn")

    def period_fn(carry, layer_p):
        h, aux = carry
        for i, kind in enumerate(period):
            h, _, a = _block_apply(kind, layer_p[i], h, positions, cfg, ctx,
                                   None, None, shared_attn)
            aux = aux + a
        if ctx is not None:
            h = _constrain(h, ctx)
        return (h, aux), None

    if remat == "full":
        period_fn = jax.checkpoint(period_fn, prevent_cse=False)
    elif remat == "dots":
        period_fn = jax.checkpoint(
            period_fn, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (h, aux), _ = lax.scan(period_fn, (h, jnp.zeros((), jnp.float32)),
                           params["layers"], unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.logits(params["embed"], h, cfg), aux


def _constrain(h, ctx):
    from jax.sharding import NamedSharding, PartitionSpec as P
    # §Perf H5: sequence-parallel residual — norms/elementwise run on S/tp
    # shards; GSPMD turns the row-parallel psum into reduce-scatter and the
    # column-parallel input into all-gather (Megatron-SP comm pattern).
    s_part = ctx.model_axis if getattr(ctx, "seq_parallel", False) else None
    return lax.with_sharding_constraint(
        h, NamedSharding(ctx.mesh, P(ctx.batch_axes if ctx.batch_axes else None,
                                     s_part, None)))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Any:
    """Cache pytree stacked over periods, mirroring the layer scan."""
    period = cfg.block_pattern
    kv_len = min(max_len, cfg.window) if cfg.window else max_len

    def one(kind):
        if kind in ("attn", "attn_moe"):
            shp = (batch, kv_len, cfg.n_kv_heads, cfg.hd)
            return {"attn": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))}
        if kind in ("mamba2", "mamba2_attn"):
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            c = {"mamba": {
                "conv": jnp.zeros((batch, s.conv_width - 1, d_in + 2 * s.d_state), dtype),
                "ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32)}}
            if kind == "mamba2_attn":
                shp = (batch, kv_len, cfg.n_kv_heads, cfg.hd)
                c["shared_attn"] = (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
            return c
        if kind == "mlstm":
            d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
            nh, hd = cfg.n_heads, d_in // cfg.n_heads
            return {"mlstm": {"ssm": jnp.zeros((batch, nh, hd, hd + 1), jnp.float32)}}
        if kind == "slstm":
            return {"slstm": X.slstm_init_cache(batch, cfg)}
        raise ValueError(kind)

    percell = tuple(one(k) for k in period)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), percell)


def supports_paged(cfg: ModelConfig) -> bool:
    """True when the paged KV-cache engine can serve this config: pure
    attention patterns (pages hold K/V lines only — recurrent state has no
    per-position layout to page) with full attention (an SWA ring is itself
    a reuse scheme; it does not compose with page chains)."""
    return (not cfg.enc_dec and cfg.window is None
            and all(k in ("attn", "attn_moe") for k in cfg.block_pattern))


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block: int,
                     dtype=jnp.bfloat16) -> Any:
    """Paged cache arena pytree, stacked over periods like ``init_cache``:
    per attention layer one (K, V) pair of ``(n_blocks, block, kv_heads,
    hd)`` pages shared by every request (``serving.BlockPool`` hands out the
    blocks; requests address them through block tables)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV cache needs a pure-attention, no-SWA pattern; got "
            f"{cfg.block_pattern} (window={cfg.window})")
    shp = (n_blocks, block, cfg.n_kv_heads, cfg.hd)
    percell = tuple({"attn": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))}
                    for _ in cfg.block_pattern)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), percell)


def supports_fused_prefill(cfg: ModelConfig) -> bool:
    """True when ``prefill`` handles arbitrary (right-padded, any-length)
    prompts: pure-attention patterns, where causal masking makes end-padding
    invisible.  Recurrent kinds (mamba2/mlstm/slstm) do support ``prefill``,
    but only for unpadded prompts whose length divides into the chunk scan —
    the serving scheduler falls back to the per-token loop for them."""
    return all(k in ("attn", "attn_moe") for k in cfg.block_pattern)


def prefill(params: Params, tokens: jax.Array, cache: Any, cfg: ModelConfig, *,
            length: Optional[jax.Array] = None, ctx=None,
            unroll: int = 1) -> Tuple[jax.Array, Any]:
    """Cache-writing full-sequence forward: one fused call replaces a
    prompt-length loop of decode steps.  tokens: (B, S) int32 starting at
    position 0; the KV cache (attention) / recurrent state (SSM, xLSTM) for
    all S tokens is written in-pass.  ``length``: optional per-row true
    prompt lengths for right-padded batches — pad entries are causally
    invisible (attention patterns only; recurrent state would absorb them).
    Returns (last-position logits (B, V) f32, new_cache)."""
    period = cfg.block_pattern
    b, s = tokens.shape
    if length is not None:
        if not supports_fused_prefill(cfg):
            raise NotImplementedError(
                "padded fused prefill needs a causally-maskable pattern; "
                f"{cfg.block_pattern} carries recurrent state")
        ring = jax.tree.leaves(cache)[0].shape[2]
        if s > ring:
            # the trailing-window ring write would keep pad K/V and drop
            # real tokens; unpadded (length=None) overflow is fine
            raise NotImplementedError(
                f"right-padded prefill bucket {s} exceeds the cache ring "
                f"{ring}; cap the pad bucket at the attention window")
    h = L.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(s)
    cache_pos = jnp.int32(0)
    shared_attn = params.get("shared_attn")

    def period_fn(h, xs):
        layer_p, cache_p = xs
        new_caches = []
        for i, kind in enumerate(period):
            h, nc, _ = _block_apply(kind, layer_p[i], h, positions, cfg, ctx,
                                    cache_p[i], cache_pos, shared_attn)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_cache = lax.scan(period_fn, h, (params["layers"], cache), unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    if length is None:
        h_last = h[:, -1]
    else:
        idx = jnp.broadcast_to(jnp.asarray(length) - 1, (b,))
        h_last = h[jnp.arange(b), idx]
    logit = L.logits(params["embed"], h_last[:, None], cfg)[:, 0]
    return logit, new_cache


def prefill_paged(params: Params, tokens: jax.Array, cache: Any,
                  cfg: ModelConfig, *, pos0, block_tables: jax.Array,
                  length=None, ctx=None,
                  unroll: int = 1) -> Tuple[jax.Array, Any]:
    """One chunked-prefill slice: tokens (1, C) land at absolute positions
    ``pos0..pos0+C-1`` of one request's paged sequence (its pages named by
    ``block_tables`` (1, P)), writing K/V into the arena and attending
    causally over everything written so far.  ``length``: true token count
    of a right-padded final chunk.  Returns (logits at the chunk's last real
    token (1, V) f32, new_cache) — only the final chunk's logits are used
    (they seed the first generated token)."""
    period = cfg.block_pattern
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens, cfg)
    positions = pos0 + jnp.arange(s)
    shared_attn = params.get("shared_attn")

    def period_fn(h, xs):
        layer_p, cache_p = xs
        new_caches = []
        for i, kind in enumerate(period):
            h, nc, _ = _block_apply(kind, layer_p[i], h, positions, cfg, ctx,
                                    cache_p[i], pos0, shared_attn,
                                    block_tables=block_tables)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_cache = lax.scan(period_fn, h, (params["layers"], cache), unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    idx = (jnp.asarray(length) if length is not None else s) - 1
    h_last = h[jnp.arange(b), jnp.broadcast_to(idx, (b,))]
    return L.logits(params["embed"], h_last[:, None], cfg)[:, 0], new_cache


def decode_step(params: Params, token: jax.Array, cache: Any, pos: jax.Array,
                cfg: ModelConfig, *, ctx=None, unroll: int = 1,
                block_tables=None) -> Tuple[jax.Array, Any]:
    """One decode step.  token: (B,) int32; pos: scalar absolute position, or
    a (B,) vector of per-row positions (continuous-batching slots advance
    independently).  ``block_tables`` (B, P): paged mode — ``cache`` is the
    page arena and each row addresses its own page chain.  Returns
    (logits (B, V) f32, new_cache)."""
    period = cfg.block_pattern
    h = L.embed(params["embed"], token[:, None], cfg)       # (B, 1, d)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos[:, None]
    cache_pos = pos if cfg.window is None else pos % cfg.window
    shared_attn = params.get("shared_attn")

    def period_fn(h, xs):
        layer_p, cache_p = xs
        new_caches = []
        for i, kind in enumerate(period):
            h, nc, _ = _block_apply(kind, layer_p[i], h, positions, cfg, ctx,
                                    cache_p[i], cache_pos, shared_attn,
                                    block_tables=block_tables)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_cache = lax.scan(period_fn, h, (params["layers"], cache), unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    logit = L.logits(params["embed"], h, cfg)[:, 0]
    return logit, new_cache
