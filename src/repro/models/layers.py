"""Shared transformer layers: norms, RoPE, GQA attention (+SWA, qk-norm,
2d-RoPE), MLPs.  Pure functions over dict-pytree params.

Conventions:
  * params are dicts of jnp arrays; init fns take an ``rng`` and a
    ``ModelConfig`` and return the dict (use ``jax.eval_shape`` for abstract
    init in the dry-run).
  * activations run in ``cfg.dtype`` (bf16), matmul accumulation and
    softmax/norm statistics in f32.
  * decode: ``cache`` is (k, v) of shape (B, L, Hkv, hd); the new token is
    written at ``pos`` (ring position for sliding windows) before attending.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import ModelConfig

Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(rng, d_in: int, d_out: int, cfg: ModelConfig, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), _pdtype(cfg)) * scale)


def dense(x: jax.Array, w: jax.Array, cfg: ModelConfig) -> jax.Array:
    # bf16 output directly: the MXU accumulates in f32 internally; keeping the
    # HLO result bf16 lets GSPMD run the TP all-reduces in bf16 (2× wire).
    dt = _dtype(cfg)
    return jnp.matmul(x.astype(dt), w.astype(dt), preferred_element_type=dt)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_init(d: int, cfg: ModelConfig) -> Params:
    p = {"scale": jnp.ones((d,), _pdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _pdtype(cfg))
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + cfg.norm_eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        xf = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(_dtype(cfg))


# ---------------------------------------------------------------------------
# RoPE (standard + fractional "2d" chatglm variant)
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (..., S, H, hd); positions: (S,) or (B, S) absolute positions."""
    hd = x.shape[-1]
    rot = int(hd * cfg.rope_fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dim: (..., S, 1, half)
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    x_rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([x_rot.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def attention_init(rng, cfg: ModelConfig) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, cfg),
        "wk": dense_init(ks[1], d, hkv * hd, cfg),
        "wv": dense_init(ks[2], d, hkv * hd, cfg),
        "wo": dense_init(ks[3], hq * hd, d, cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), _pdtype(cfg))}
        p["k_norm"] = {"scale": jnp.ones((hd,), _pdtype(cfg))}
    return p


def _qk_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset: int | jax.Array,
          kv_len_valid=None) -> jax.Array:
    """Grouped SDPA.  q: (B, Lq, Hkv, rep, hd); k, v: (B, Lk, Hkv, hd).
    ``q_offset``: absolute position of q[0] minus first key position —
    scalar, or (B,) for per-row positions (continuous-batching decode).
    ``kv_len_valid``: number of valid cache slots (decode with a partially
    filled cache) — scalar or (B,)."""
    b, lq, hkv, rep, hd = q.shape
    lk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # bf16 operands, f32 accumulation (MXU-native); stats in f32
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q * scale, k,
                   preferred_element_type=jnp.float32)
    q_off = jnp.asarray(q_offset)
    # (Lq,) for scalar offsets, (B, Lq) for per-row offsets
    qpos = jnp.arange(lq) + (q_off[..., None] if q_off.ndim else q_off)
    kpos = jnp.arange(lk)
    mask = jnp.ones(qpos.shape + (lk,), bool)
    if causal:
        mask &= kpos <= qpos[..., None]
    if window is not None:
        mask &= qpos[..., None] - kpos < window
    if kv_len_valid is not None:
        kvv = jnp.asarray(kv_len_valid)
        mask = mask & (kpos < (kvv[..., None, None] if kvv.ndim else kvv))
    if mask.ndim == 3:                      # per-row mask: (B, 1, 1, Lq, Lk)
        mask = mask[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v,
                     preferred_element_type=q.dtype)
    return out


def _cstr(x, ctx, parts):
    """with_sharding_constraint if a MeshCtx is given (else no-op)."""
    if ctx is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*parts)))


def _sdpa_manual(q, k, v, ctx, *, causal, window):
    """Sequence-sharded attention with a manual shard_map over ``model``:
    each shard holds S/p query rows (full heads) and the full (GQA-small)
    K/V; the causal mask offsets by the shard's global row base."""
    import jax
    from jax.sharding import PartitionSpec as P
    M = ctx.model_axis
    s_loc = q.shape[1] // ctx.model_size
    assert q.shape[1] % ctx.model_size == 0

    def body(ql, kl, vl):
        off = lax.axis_index(M) * s_loc
        return _sdpa(ql, kl, vl, causal=causal, window=window, q_offset=off)

    # fully manual over the mesh (axis_index inside a partial-manual region
    # lowers to PartitionId, which SPMD partitioning rejects): batch over the
    # batch axes, sequence over model.
    bat = ctx.batch_axes if ctx.batch_axes else None
    from repro.core.compat import shard_map as _shard_map
    return _shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bat, M, None, None, None), P(bat, None, None, None),
                  P(bat, None, None, None)),
        out_specs=P(bat, M, None, None, None),
        check=False)(q, k, v)


def attention(p: Params, x: jax.Array, positions: jax.Array, cfg: ModelConfig, *,
              causal: bool = True,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              xattn_kv: Optional[jax.Array] = None,
              block_tables: Optional[jax.Array] = None,
              ctx=None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Self- (or cross-) attention.

    Train/prefill: ``cache=None`` — full causal attention over x.
    Decode: ``cache=(k, v)`` of length L; the new token's k/v are written at
    ``cache_pos`` (already ring-reduced for SWA), then q attends to the cache.
    Paged decode/prefill: ``block_tables`` given — ``cache`` is the shared
    page *arena* ``(n_blocks, block, Hkv, hd)`` and each request reads/writes
    through its block-table row (the page view; ``serving/kvcache.py`` owns
    the host-side allocation).
    Cross-attention (whisper): ``xattn_kv`` is the encoder output; keys/values
    are computed from it, no cache/causality.

    Distribution (Ulysses-style, DESIGN.md §3): heads are never sharded (GQA
    head counts rarely divide TP); instead the attention einsum region is
    *sequence-sharded* over ``model`` — GSPMD reshards proj outputs with an
    all-to-all (Table-1 ``allToAllD``), each shard computes full-head
    attention on S/p query rows against replicated (small, GQA) K/V, and the
    output all-to-alls back to feature sharding for the row-parallel wo.
    Decode shards the *cache length* over ``model`` instead (softmax stats
    combine with tiny psums).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rep = hq // hkv
    B = ctx.batch_axes if (ctx and ctx.batch_axes) else None
    M = ctx.model_axis if ctx else None
    if ctx is not None and getattr(ctx, "dp_over_model", False):
        M = None  # pure DP: attention is local per batch shard

    q = dense(x, p["wq"], cfg).reshape(b, s, hkv, rep, hd)
    kv_src = xattn_kv if xattn_kv is not None else x
    k = dense(kv_src, p["wk"], cfg).reshape(b, -1, hkv, hd)
    v = dense(kv_src, p["wv"], cfg).reshape(b, -1, hkv, hd)

    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"]["scale"], cfg.norm_eps)

    if xattn_kv is None:
        q = rope(q.reshape(b, s, hkv * rep, hd), positions, cfg).reshape(b, s, hkv, rep, hd)
        k = rope(k, positions, cfg)

    new_cache = None
    if cache is not None and block_tables is not None:
        # ---- paged cache: requests read/write the shared page arena
        # through their block-table rows (chains of fixed-size pages replace
        # the per-slot end-aligned row, so prompt+gen is bounded by pool
        # capacity, not slot length).  SWA rings and paging don't compose.
        assert cfg.window is None, "paged attention needs full (no-SWA) attention"
        ck, cv = cache                    # (n_blocks, block, Hkv, hd) arenas
        n_blocks, blk = ck.shape[0], ck.shape[1]
        if jnp.ndim(cache_pos) == 1:
            # decode: each request writes its token at page pos//block,
            # offset pos%block of its own chain; rows whose table entry is
            # -1 (parked/free slots) map OOB and the write drops
            pg, off = cache_pos // blk, cache_pos % blk
            entry = jnp.take_along_axis(block_tables, pg[:, None], axis=1)[:, 0]
            phys = jnp.where(entry >= 0, entry, n_blocks)
            ck = ck.at[phys, off].set(k[:, 0].astype(ck.dtype), mode="drop")
            cv = cv.at[phys, off].set(v[:, 0].astype(cv.dtype), mode="drop")
            from repro.kernels.paged_attention import paged_attention
            out = paged_attention(q[:, 0], ck, cv, block_tables,
                                  cache_pos + 1)[:, None]
        else:
            # chunked prefill (one request, B=1): the chunk's tokens land at
            # absolute positions cache_pos..cache_pos+s-1 through the table,
            # then attend causally against the gathered page view.  Writes
            # from right-pad tokens are harmless: every position is
            # re-written by its real token (next chunk / decode step) before
            # any query ever attends to it, and pad queries' outputs are
            # dropped by the length pick.
            assert b == 1, "chunked prefill runs one request per call"
            tpos = cache_pos + jnp.arange(s)
            pg, off = tpos // blk, tpos % blk
            # pad-token positions can run past the table width; an unguarded
            # gather would CLAMP to the last (live!) entry and scatter pad
            # K/V over real tokens — route them OOB so the write drops
            n_pages = block_tables.shape[1]
            entry = jnp.where(pg < n_pages,
                              block_tables[0, jnp.minimum(pg, n_pages - 1)],
                              -1)
            phys = jnp.where(entry >= 0, entry, n_blocks)
            ck = ck.at[phys, off].set(k[0].astype(ck.dtype), mode="drop")
            cv = cv.at[phys, off].set(v[0].astype(cv.dtype), mode="drop")
            idx = jnp.maximum(block_tables, 0)
            out = _sdpa(q, ck[idx].reshape(b, -1, hkv, hd),
                        cv[idx].reshape(b, -1, hkv, hd),
                        causal=True, window=None, q_offset=cache_pos)
        new_cache = (ck, cv)
    elif cache is not None:
        ck, cv = cache  # (B, L, Hkv, hd), L sharded over model
        lk = ck.shape[1]
        if jnp.ndim(cache_pos) == 1:
            # per-row positions (continuous-batching decode, s == 1): scatter
            # each row's token at its own slot; OOB rows (parked slots) drop
            bidx = jnp.arange(b)
            ck = ck.at[bidx, cache_pos].set(k[:, 0].astype(ck.dtype), mode="drop")
            cv = cv.at[bidx, cache_pos].set(v[:, 0].astype(cv.dtype), mode="drop")
        elif s > lk:
            # fused SWA prefill, prompt longer than the ring: keep the last
            # lk tokens at their ring slots (token j -> slot j % lk)
            slots = np.arange(s - lk, s) % lk
            ck = ck.at[:, slots].set(k[:, s - lk:].astype(ck.dtype))
            cv = cv.at[:, slots].set(v[:, s - lk:].astype(cv.dtype))
        else:
            ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
            cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
        ck = _cstr(ck, ctx, (B, M, None, None))
        cv = _cstr(cv, ctx, (B, M, None, None))
        new_cache = (ck, cv)
        q = _cstr(q, ctx, (B, None, None, None, None))
        if s > lk:
            # prefill longer than the ring: attend the full in-flight k/v
            # (the cache holds only the trailing window)
            out = _sdpa(q, k, v, causal=True, window=cfg.window, q_offset=0)
        elif cfg.window is not None and lk == cfg.window and s == 1:
            # ring cache decode: slot validity from the absolute position —
            # before the first wrap only pos+1 slots hold real tokens (the
            # untouched zero-k/v slots would otherwise soak up softmax mass)
            valid = jnp.minimum(positions[..., -1] + 1, lk)
            out = _sdpa(q, ck, cv, causal=False, window=None, q_offset=0,
                        kv_len_valid=valid)
        else:
            # end-aligned: query position == cache_pos
            out = _sdpa(q, ck, cv, causal=True, window=cfg.window,
                        q_offset=cache_pos)
    elif ctx is not None and getattr(ctx, "manual_attention", False) and s > 1 \
            and not getattr(ctx, "dp_over_model", False):
        # §Perf A8: the einsum region as a *manual* shard_map over model on
        # the S dim — GSPMD cannot re-shard inside (kills the involuntary
        # q-replication all-gathers the constraint-based path suffers)
        out = _sdpa_manual(q, k, v, ctx, causal=causal and xattn_kv is None,
                           window=cfg.window)
    else:
        # sequence-sharded einsum region (all-to-all in, all-to-all out)
        q = _cstr(q, ctx, (B, M, None, None, None))
        k = _cstr(k, ctx, (B, None, None, None))
        v = _cstr(v, ctx, (B, None, None, None))
        out = _sdpa(q, k, v, causal=causal and xattn_kv is None,
                    window=cfg.window, q_offset=0)
        out = _cstr(out, ctx, (B, M, None, None, None))

    out = out.reshape(b, s, hq * hd)
    out = _cstr(out, ctx, (B, None, M))
    return dense(out, p["wo"], cfg), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {"w_gate": dense_init(ks[0], d, ff, cfg),
                "w_up": dense_init(ks[1], d, ff, cfg),
                "w_down": dense_init(ks[2], ff, d, cfg)}
    return {"w_up": dense_init(ks[0], d, ff, cfg),
            "w_down": dense_init(ks[1], ff, d, cfg)}


def mlp(p: Params, x: jax.Array, cfg: ModelConfig, ctx=None) -> jax.Array:
    if ctx is not None and getattr(ctx, "foopar_tp", False):
        return _mlp_foopar(p, x, cfg, ctx)
    if "w_gate" in p:
        g = dense(x, p["w_gate"], cfg)
        u = dense(x, p["w_up"], cfg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    else:
        h = jax.nn.gelu(dense(x, p["w_up"], cfg).astype(jnp.float32)).astype(_dtype(cfg))
    return dense(h, p["w_down"], cfg)


def _mlp_foopar(p: Params, x: jax.Array, cfg: ModelConfig, ctx) -> jax.Array:
    """Paper-faithful TP MLP: the FooPar algebra's column-parallel mapD for
    the up/gate projections and zipWithD∘reduceD('sum') for the down
    projection (core/tensor_ops.py) — same math as the pjit path; §Perf
    compares the compiled collective schedules."""
    from repro.core.tensor_ops import foopar_matmul_col, foopar_matmul_row
    dt = _dtype(cfg)
    mesh, ax = ctx.mesh, ctx.model_axis
    xx = x.astype(dt)
    if "w_gate" in p:
        g = foopar_matmul_col(xx, p["w_gate"].astype(dt), mesh=mesh, axis=ax,
                              preferred_element_type=dt)
        u = foopar_matmul_col(xx, p["w_up"].astype(dt), mesh=mesh, axis=ax,
                              preferred_element_type=dt)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    else:
        h = jax.nn.gelu(foopar_matmul_col(xx, p["w_up"].astype(dt), mesh=mesh,
                                          axis=ax, preferred_element_type=dt)
                        .astype(jnp.float32)).astype(dt)
    return foopar_matmul_row(h, p["w_down"].astype(dt), mesh=mesh, axis=ax,
                             preferred_element_type=dt)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------
def embed_init(rng, cfg: ModelConfig) -> Params:
    p = {"embedding": jax.random.normal(rng, (cfg.vocab, cfg.d_model), _pdtype(cfg)) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(rng, 1), cfg.d_model, cfg.vocab, cfg)
    return p


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(_dtype(cfg))


def logits(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["embedding"].T if cfg.tie_embeddings else p["unembed"]
    out = jnp.matmul(x.astype(_dtype(cfg)), w.astype(_dtype(cfg)),
                     preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = c * jnp.tanh(out / c)
    return out  # f32
