"""Whisper-style encoder-decoder backbone (conv/audio frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings, per the assignment).

Encoder: non-causal self-attention + GELU MLP over frame embeddings.
Decoder: causal self-attention (KV-cached for decode) + cross-attention to
the encoder output + GELU MLP.  LayerNorm, learned positional embeddings.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import layers as L

Params = dict

ENC_LEN = 1500  # whisper 30 s @ 50 Hz after the (stubbed) conv frontend


def _xattn_init(rng, cfg):
    return L.attention_init(rng, cfg)


def init(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 8)
    n = cfg.n_layers

    def enc_layer(r):
        kk = jax.random.split(r, 2)
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "attn": L.attention_init(kk[0], cfg),
                "ln2": L.norm_init(cfg.d_model, cfg),
                "mlp": L.mlp_init(kk[1], cfg)}

    def dec_layer(r):
        kk = jax.random.split(r, 3)
        return {"ln1": L.norm_init(cfg.d_model, cfg),
                "attn": L.attention_init(kk[0], cfg),
                "lnx": L.norm_init(cfg.d_model, cfg),
                "xattn": _xattn_init(kk[1], cfg),
                "ln2": L.norm_init(cfg.d_model, cfg),
                "mlp": L.mlp_init(kk[2], cfg)}

    enc_rngs = jax.random.split(ks[0], n)
    dec_rngs = jax.random.split(ks[1], n)
    return {
        "embed": L.embed_init(ks[2], cfg),
        "enc_pos": jax.random.normal(ks[3], (ENC_LEN, cfg.d_model), jnp.float32) * 0.01,
        "dec_pos": jax.random.normal(ks[4], (32768, cfg.d_model), jnp.float32) * 0.01,
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *[enc_layer(r) for r in enc_rngs]),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *[dec_layer(r) for r in dec_rngs]),
        "enc_norm": L.norm_init(cfg.d_model, cfg),
        "final_norm": L.norm_init(cfg.d_model, cfg),
    }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig, *, remat="none",
           ctx=None, unroll: int = 1) -> jax.Array:
    """frames: (B, T_enc, d) stub frame embeddings."""
    t = frames.shape[1]
    h = frames.astype(L._dtype(cfg)) + params["enc_pos"][:t].astype(L._dtype(cfg))
    positions = jnp.arange(t)

    def layer_fn(h, p):
        a, _ = L.attention(p["attn"], L.apply_norm(p["ln1"], h, cfg), positions,
                           cfg, causal=False, ctx=ctx)
        h = h + a
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
        h = _constrain(h, ctx)
        return h, None

    if remat != "none":
        layer_fn = jax.checkpoint(layer_fn, prevent_cse=False)
    h, _ = lax.scan(layer_fn, h, params["enc_layers"], unroll=unroll)
    return L.apply_norm(params["enc_norm"], h, cfg)


def decode_train(params: Params, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig, *, remat="none", ctx=None, unroll: int = 1) -> jax.Array:
    """Teacher-forced decoder pass.  Returns logits (B, S, V) f32."""
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens, cfg) + params["dec_pos"][:s].astype(L._dtype(cfg))
    positions = jnp.arange(s)

    def layer_fn(h, p):
        a, _ = L.attention(p["attn"], L.apply_norm(p["ln1"], h, cfg), positions, cfg,
                           ctx=ctx)
        h = h + a
        xa, _ = L.attention(p["xattn"], L.apply_norm(p["lnx"], h, cfg), positions,
                            cfg, xattn_kv=enc_out, ctx=ctx)
        h = h + xa
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
        h = _constrain(h, ctx)
        return h, None

    if remat != "none":
        layer_fn = jax.checkpoint(layer_fn, prevent_cse=False)
    h, _ = lax.scan(layer_fn, h, params["dec_layers"], unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.logits(params["embed"], h, cfg)


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, *, remat="none", ctx=None,
            unroll: int = 1) -> Tuple[jax.Array, jax.Array]:
    enc = encode(params, frames, cfg, remat=remat, ctx=ctx, unroll=unroll)
    return decode_train(params, tokens, enc, cfg, remat=remat, ctx=ctx,
                        unroll=unroll), jnp.zeros((), jnp.float32)


def _constrain(h, ctx):
    if ctx is None:
        return h
    from jax.sharding import NamedSharding, PartitionSpec as P
    return lax.with_sharding_constraint(
        h, NamedSharding(ctx.mesh, P(ctx.batch_axes if ctx.batch_axes else None,
                                     None, None)))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Any:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    one = {"attn": (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))}
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def decode_prefill(params: Params, tokens: jax.Array, enc_out: jax.Array,
                   cache: Any, cfg: ModelConfig, *,
                   length: Optional[jax.Array] = None, ctx=None,
                   unroll: int = 1) -> Tuple[jax.Array, Any]:
    """Cache-writing full-sequence decoder pass: one fused call replaces a
    prompt-length loop of decode steps.  tokens: (B, S) int32 starting at
    position 0; every prompt token's self-attention K/V is written into the
    cache in-pass.  ``length``: optional per-row true prompt lengths for
    right-padded batches (pad entries are causally invisible).
    Returns (last-position logits (B, V) f32, new_cache)."""
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens, cfg) + params["dec_pos"][:s].astype(L._dtype(cfg))
    positions = jnp.arange(s)
    cache_pos = jnp.int32(0)

    def layer_fn(h, xs):
        p, c = xs
        a, c_new = L.attention(p["attn"], L.apply_norm(p["ln1"], h, cfg), positions,
                               cfg, cache=c["attn"], cache_pos=cache_pos, ctx=ctx)
        h = h + a
        xa, _ = L.attention(p["xattn"], L.apply_norm(p["lnx"], h, cfg), positions,
                            cfg, xattn_kv=enc_out, ctx=ctx)
        h = h + xa
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
        return h, {"attn": c_new}

    h, new_cache = lax.scan(layer_fn, h, (params["dec_layers"], cache), unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    if length is None:
        h_last = h[:, -1]
    else:
        idx = jnp.broadcast_to(jnp.asarray(length) - 1, (b,))
        h_last = h[jnp.arange(b), idx]
    return L.logits(params["embed"], h_last[:, None], cfg)[:, 0], new_cache


def decode_step(params: Params, token: jax.Array, cache: Any, pos: jax.Array,
                enc_out: jax.Array, cfg: ModelConfig, *, unroll: int = 1,
                ctx=None) -> Tuple[jax.Array, Any]:
    """One decoder step with cached self-attention; cross-attention recomputes
    K/V from enc_out (B, T_enc, d).  pos: scalar, or (B,) per-row positions."""
    b = token.shape[0]
    positions = pos[None] if jnp.ndim(pos) == 0 else pos[:, None]
    h = L.embed(params["embed"], token[:, None], cfg) + \
        jnp.take(params["dec_pos"], positions, axis=0).astype(L._dtype(cfg))

    def layer_fn(h, xs):
        p, c = xs
        a, c_new = L.attention(p["attn"], L.apply_norm(p["ln1"], h, cfg), positions,
                               cfg, cache=c["attn"], cache_pos=pos, ctx=ctx)
        h = h + a
        xa, _ = L.attention(p["xattn"], L.apply_norm(p["lnx"], h, cfg), positions,
                            cfg, xattn_kv=enc_out, ctx=ctx)
        h = h + xa
        h = h + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
        return h, {"attn": c_new}

    h, new_cache = lax.scan(layer_fn, h, (params["dec_layers"], cache), unroll=unroll)
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.logits(params["embed"], h, cfg)[:, 0], new_cache
