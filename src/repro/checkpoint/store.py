"""Sharded checkpointing without orbax: one .npy per leaf per host-shard,
a JSON manifest, and atomic step-fenced commits.

Layout:
  <dir>/step_<k>.tmp/         — in-progress write
  <dir>/step_<k>/             — committed (atomic rename)
      manifest.json           — tree structure, shapes, dtypes
      <leafpath>.proc<i>.npy  — this process's addressable shard data

Restart: ``restore_checkpoint`` reads the manifest, rebuilds the pytree, and
``jax.device_put``s onto the *current* mesh — so a restore after an elastic
resize (different data-axis extent) reshards transparently: leaves are saved
as full logical arrays per process-shard slice and reassembled by index.

On a single-process container each leaf is simply the full array; the
process-sharded path is exercised by the same code with process_count==1.

``AsyncCheckpointer`` moves serialization + fsync off the training thread
(checkpoint/restart is the fault-tolerance backbone — see runtime/recovery).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "__"


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    manifest = {}
    pidx = jax.process_index()
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{key}.proc{pidx}.npy"), arr)
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if pidx == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest,
                       "process_count": jax.process_count()}, f)
    os.replace(tmp, final)  # atomic commit fence
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Pytree,
                       shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``like`` (abstract or concrete), placing
    leaves with ``shardings`` if given (elastic resharding = just a different
    sharding tree here)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else (None, None)
    leaves = []
    for key in flat_like:
        arr = np.load(os.path.join(path, f"{key}.proc0.npy"))
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[key]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Fire-and-forget background checkpoint writer with a single in-flight
    slot (back-pressure if the previous save hasn't finished)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[int] = None

    def save(self, step: int, tree: Pytree) -> None:
        self.wait()
        # materialize on host *before* backgrounding (device buffers may be
        # donated/overwritten by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            save_checkpoint(self.directory, step, host_tree)
            self.last_committed = step

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
