"""Config system: model architecture, parallelism, and input-shape configs.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
input shapes are ``ShapeConfig`` (train / prefill / decode / long-decode).
Configs are plain frozen dataclasses — no registry magic beyond
``repro.configs.get(name)``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0      # dense experts always active (Kimi-style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2                # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length
    unroll: int = 1                # chunk-scan unroll (dry-run flop probing)
    mm_bf16: bool = False          # engine matmuls in bf16 (§Perf H8)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8           # one sLSTM block per this many layers
    proj_factor: float = 2.0       # mLSTM up-projection
    chunk: int = 256
    unroll: int = 1                # chunk-scan unroll (dry-run flop probing)
    mm_bf16: bool = False          # engine matmuls in bf16 (§Perf H8)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # block structure: period of layer kinds, tiled to n_layers
    block_pattern: Tuple[str, ...] = ("attn",)   # attn|mamba2|mamba2_attn|mlstm|slstm
    # attention details
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0               # chatglm 2d-RoPE = 0.5
    window: Optional[int] = None             # sliding-window attention
    qk_norm: bool = False                    # chameleon
    parallel_block: bool = False             # command-r style attn ∥ mlp
    # norms / act
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "gelu"] = "swiglu"
    norm_eps: float = 1e-5
    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    enc_dec: bool = False                    # whisper
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    # numerics
    dtype: str = "bfloat16"                  # activation/compute dtype
    param_dtype: str = "float32"             # master params
    # notes for DESIGN/EXPERIMENTS
    sub_quadratic: bool = False              # eligible for long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> Tuple[str, ...]:
        return self.block_pattern

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (drives MODEL_FLOPS and memory estimates) ----
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.hd
        counts: dict = {}
        counts["embed"] = self.vocab * d
        counts["unembed"] = 0 if self.tie_embeddings else self.vocab * d
        per_kind = {}
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        mlp_mult = 3 if self.act == "swiglu" else 2
        per_kind["attn"] = attn + mlp_mult * d * self.d_ff + 2 * d
        if self.moe:
            e = self.moe
            experts = e.n_experts * mlp_mult * d * e.d_ff_expert
            shared = e.n_shared_experts * mlp_mult * d * e.d_ff_expert
            router = d * e.n_experts
            per_kind["attn_moe"] = attn + experts + shared + router + 2 * d
        if self.ssm:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_kind["mamba2"] = (d * (2 * d_in + 2 * s.d_state + nh)
                                  + s.conv_width * (d_in + 2 * s.d_state)
                                  + 2 * nh + d_in * d + 2 * d)
            per_kind["mamba2_attn"] = per_kind["mamba2"]  # shared attn counted once below
        if self.xlstm:
            f = self.xlstm
            d_in = int(f.proj_factor * d)
            per_kind["mlstm"] = d * 2 * d_in + 3 * d_in * d_in // 1 + d_in * d + 2 * d
            per_kind["slstm"] = 4 * 2 * d * d + d * d + 2 * d
        total = counts["embed"] + counts["unembed"]
        for kind in self.block_pattern:
            base = kind if kind in per_kind else "attn"
            total += per_kind[base] * self.n_periods
        if "mamba2_attn" in self.block_pattern:
            total += attn + mlp_mult * d * self.d_ff  # one shared block
        counts["total"] = total
        # active (MoE: only top_k + shared experts per token)
        active = total
        if self.moe:
            e = self.moe
            dead = (e.n_experts - e.top_k) * mlp_mult * d * e.d_ff_expert
            active = total - dead * self.block_pattern.count("attn_moe") * self.n_periods
        counts["active"] = active
        return counts


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How the model is laid out on the mesh."""
    fsdp_params: bool = True        # shard params over 'data' (ZeRO-3 style)
    fsdp_pod: bool = False          # extend param/opt sharding over 'pod'
    grad_reduce: Literal["all_reduce", "reduce_scatter_zero"] = "all_reduce"
    # ^ reduce_scatter_zero: grads reduce-scattered over the fsdp/data axes,
    #   AdamW updates only the local shard, params all-gathered (ZeRO)
    opt_state_dtype: str = "float32"   # float32|bfloat16 (compression)
    grad_dtype: str = "bfloat16"       # gradient all-reduce compression
    remat: Literal["none", "dots", "full"] = "full"
    sequence_parallel: bool = False
    use_flash_kernel: bool = False  # Pallas attention inside shard_map
    use_foopar_tp: bool = False     # algebra-based TP matmuls (paper-faithful)
    logit_chunk: Optional[int] = None  # chunked CE loss over sequence
    scan_unroll: int = 1            # layer-scan unroll (dry-run flop probing)
    moe_a2a_ep: bool = False        # token-routing EP (tokens move, not weights)
    engine_replicate: bool = False  # SSM/mLSTM engine: batch-shard only (§Perf)
    master_weights: bool = False    # bf16 params + f32 master in opt (§Perf)
    grad_barrier: bool = False      # optimization_barrier on grads (§Perf)
    manual_attention: bool = False  # manual shard_map SDPA region (§Perf)
    dp_over_model: bool = False     # pure DP: batch over BOTH axes (§Perf C7)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
