"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = lr * (s + 1.0) / max(warmup_steps, 1)
    t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup_steps, warm, cos)
