"""AdamW from scratch (no optax), with state-dtype compression.

Optimizer states inherit the parameter sharding (ZeRO-1 falls out of the
FSDP param specs: m/v are sharded exactly like the params they track).
``state_dtype='bfloat16'`` halves optimizer HBM — required for the ≥100B
configs on 16 GiB chips (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def adamw_init(params: Pytree, state_dtype: str = "float32",
               master: bool = False) -> Pytree:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    st = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        # f32 master copy (params themselves stored bf16 => bf16 FSDP
        # gathers and bf16 gradient reductions — §Perf mixed precision)
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree: Pytree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads: Pytree, opt_state: Pytree, params: Pytree, *,
                 lr: jax.Array | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1) -> Tuple[Pytree, Pytree]:
    """Returns (new_params, new_opt_state).  All math in f32; m/v stored in
    their configured dtype; decoupled weight decay on matrices only (ndim>1)."""
    step = opt_state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    class _Upd:  # opaque (non-pytree) tuple so param trees may contain tuples
        __slots__ = ("p", "m", "v", "w")

        def __init__(self, p, m, v, w):
            self.p, self.m, self.v, self.w = p, m, v, w

    has_master = "master" in opt_state

    def upd(g, m, v, p, mast):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        base = mast.astype(jnp.float32) if mast is not None else p.astype(jnp.float32)
        if p.ndim > 1:
            delta = delta + weight_decay * base
        p_new = base - lr * delta
        return _Upd(p_new.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype), p_new if mast is not None else None)

    masters = opt_state["master"] if has_master else jax.tree.map(lambda _: None, params)
    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params,
                       masters, is_leaf=lambda x: x is None)
    pick = lambda attr: jax.tree.map(lambda t: getattr(t, attr), out,
                                     is_leaf=lambda x: isinstance(x, _Upd))
    new = {"m": pick("m"), "v": pick("v"), "step": step}
    if has_master:
        new["master"] = pick("w")
    return pick("p"), new


def adamw_update_zero(grads: Pytree, opt_state: Pytree, params: Pytree, *,
                      scatter: Pytree, gather: Pytree, lr: jax.Array | float,
                      b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                      weight_decay: float = 0.1) -> Tuple[Pytree, Pytree]:
    """ZeRO sharded-update path (Rajbhandari et al. §5).

    ``scatter`` is the sharding tree of the grad reduce-scatter layout
    (``sharding.scatter_specs``), ``gather`` the params' storage layout.
    Constraining the grads to ``scatter`` turns the partitioner's gradient
    all-reduce into a reduce-scatter; the elementwise AdamW math then runs
    on the local 1/p shard only (m/v/master are stored in — or moved to —
    the same layout), and the single output constraint to ``gather``
    all-gathers the updated params for the next forward.  The per-element
    arithmetic is ``adamw_update`` verbatim, so the trajectory matches the
    all-reduce step."""
    wsc = jax.lax.with_sharding_constraint
    grads = wsc(grads, scatter)
    params_local = wsc(params, scatter)
    opt_local = dict(opt_state, m=wsc(opt_state["m"], scatter),
                     v=wsc(opt_state["v"], scatter))
    if "master" in opt_state:
        opt_local["master"] = wsc(opt_state["master"], scatter)
    p_new, new_state = adamw_update(grads, opt_local, params_local, lr=lr,
                                    b1=b1, b2=b2, eps=eps,
                                    weight_decay=weight_decay)
    return wsc(p_new, gather), new_state
