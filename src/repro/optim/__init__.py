from .adamw import adamw_init, adamw_update, global_norm, clip_by_global_norm
from .schedule import warmup_cosine
