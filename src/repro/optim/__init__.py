from .adamw import (adamw_init, adamw_update, adamw_update_zero, global_norm,
                    clip_by_global_norm)
from .schedule import warmup_cosine
