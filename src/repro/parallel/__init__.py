from .sharding import param_specs, make_ctx, batch_spec, shard_params
from .steps import make_train_step, make_prefill_step, make_decode_step, make_loss_fn
