"""Cost-driven auto-parallel planner: enumerate the layout lattice, reject
memory-infeasible points, rank the rest by predicted step time.

The paper's core claim is that the Table-1 cost model lets you *pick* the
parallel layout analytically instead of guessing a config.  ``ParallelPlan``
is the first-class layout object (what ``ParallelConfig`` fields used to
encode ad hoc); ``plan_search`` scores every valid point of the lattice with
``costmodel.train_memory_bytes`` / ``train_step_cost`` and returns them
ranked; ``default_plan`` is the drop-in replacement for the old hand-written
``launch/dryrun.default_pcfg`` rule table.

Every cost term maps to a Table-1 collective of the paper:

  | term      | collective (Table 1)           | cost shape                     |
  |-----------|--------------------------------|--------------------------------|
  | tp_comm_s | reduceD pair per layer (XLA    | 4L · 2(t_s log p + t_w m (p-1)/p) |
  |           | all-reduce = RS+AG)            |                                |
  | gather_s  | allGatherD of the FSDP param   | 2 · (p-1)(t_s + t_w m)         |
  |           | shard, fwd + bwd               |                                |
  | grad_s    | all_reduce: reduceD pair;      | 2(t_s log p + t_w m (p-1)/p)   |
  |           | zero: ring reduceScatterD      | (p-1)(t_s + t_w m/p)           |
  |           |   + allGatherD of the updated  | + (p-1)(t_s + t_w m/p)         |
  |           |   param shard                  |                                |
  | ep_s      | allToAllD token dispatch+      | 2(t_s log p + t_w m (p-1))     |
  |           | return (a2a expert layout)     |                                |
  | update_s  | mapD (no comm): optimizer HBM  | bytes / (shard · HBM_BW)       |
  |           | traffic on the local shard     |                                |

The layout the search mostly picks for training is the ZeRO one
(Rajbhandari et al.): grads reduce-scattered, optimizer updating only the
local shard, params all-gathered — Θ(2m (p-1)/p) wire and 1/p of the
optimizer memory/traffic vs the all-reduce step's Θ(4m (p-1)/p) wire plus p
redundant full updates.  ``parallel/steps.make_train_step_zero`` implements
it; the oracle test pins its trajectory to the all-reduce step's.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.config import ModelConfig, ParallelConfig
from repro.core import costmodel
from repro.core.costmodel import HBM_PER_CHIP, ICI, LinkClass


@dataclass(frozen=True)
class ParallelPlan:
    """One point of the layout lattice — the first-class parallel layout.

    ``to_pcfg()`` bridges to the ``ParallelConfig`` the model/step code
    consumes; the plan itself carries the mesh geometry the config never
    knew, which is what makes it scoreable."""
    mesh_shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    fsdp_axes: Tuple[str, ...] = ("data",)   # () = params replicated
    tp: int = 16                             # model-axis degree (1 = TP off)
    ep_mode: str = "none"                    # none | shard | a2a  (MoE)
    dp_over_model: bool = False              # TP off: batch over both axes
    grad: str = "all_reduce"                 # all_reduce | reduce_scatter_zero
    remat: str = "full"                      # none | dots | full
    grad_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    master_weights: bool = False

    @property
    def chips(self) -> int:
        return math.prod(self.mesh_shape)

    @property
    def model_size(self) -> int:
        return self.mesh_shape[self.axis_names.index("model")]

    @property
    def dp(self) -> int:
        """Grad-reduction group: every chip not used for TP."""
        return self.chips // self.tp

    @property
    def fsdp_shard(self) -> int:
        s = 1
        for a in self.fsdp_axes:
            s *= self.mesh_shape[self.axis_names.index(a)]
        return s

    def to_pcfg(self) -> ParallelConfig:
        return ParallelConfig(
            fsdp_params=bool(self.fsdp_axes),
            fsdp_pod="pod" in self.fsdp_axes,
            grad_reduce=self.grad if self.grad != "none" else "all_reduce",
            opt_state_dtype=self.opt_state_dtype,
            grad_dtype=self.grad_dtype,
            remat=self.remat,
            moe_a2a_ep=self.ep_mode == "a2a",
            master_weights=self.master_weights,
            dp_over_model=self.dp_over_model,
        )

    def label(self) -> str:
        fsdp = "+".join(self.fsdp_axes) if self.fsdp_axes else "off"
        grad = {"all_reduce": "allreduce", "reduce_scatter_zero": "zero",
                "none": "-"}[self.grad]
        bits = [f"fsdp={fsdp}", f"tp={self.tp}", f"grad={grad}",
                f"remat={self.remat}",
                f"opt={'bf16' if self.opt_state_dtype == 'bfloat16' else 'f32'}"]
        if self.ep_mode != "none":
            bits.insert(3, f"ep={self.ep_mode}")
        return " ".join(bits)


@dataclass(frozen=True)
class RankedPlan:
    plan: ParallelPlan
    cost: dict                    # costmodel.train_step_cost terms (+ ep_s)
    memory: dict                  # costmodel.train_memory_bytes breakdown
    feasible: bool

    @property
    def total_s(self) -> float:
        return self.cost["total_s"]


def _dtype_bytes(name: str) -> int:
    return 2 if name in ("bfloat16", "float16") else 4


def _ep_cost(cfg: ModelConfig, plan: ParallelPlan, batch_local: int,
             seq: int, link: LinkClass) -> float:
    """a2a expert layout: token dispatch + return — two allToAllD of the
    per-destination token slab (Table-1 Θ(t_s log p + t_w m (p-1)))."""
    if plan.ep_mode != "a2a" or cfg.moe is None:
        return 0.0
    ep = plan.model_size
    n_moe = cfg.block_pattern.count("attn_moe") * (
        cfg.n_layers // len(cfg.block_pattern))
    m = batch_local * seq * cfg.d_model * 2 * cfg.moe.top_k / max(ep, 1)
    return 2.0 * n_moe * costmodel.t_all_to_all(m, ep, link) * 3  # fwd+bwd

def plan_search(cfg: ModelConfig, mesh_shape: Tuple[int, ...] = (16, 16),
                batch: int = 256, seq: int = 4096, kind: str = "train", *,
                axis_names: Optional[Tuple[str, ...]] = None,
                hbm: float = HBM_PER_CHIP, budget: float = 0.9,
                link: LinkClass = ICI,
                peak_flops: float = costmodel.PEAK_FLOPS_BF16,
                hbm_bw: float = costmodel.HBM_BW) -> List[RankedPlan]:
    """Enumerate the valid plan lattice for ``cfg`` on a mesh, reject points
    whose training state doesn't fit ``budget · hbm`` per device, and return
    every point ranked: feasible plans by predicted step time (deterministic
    tie-break on the label), then infeasible ones by how far over memory
    they are — so the head of the list is always the best *runnable* plan
    and the list is never empty."""
    if axis_names is None:
        axis_names = ("pod", "data", "model") if len(mesh_shape) == 3 \
            else ("data", "model")
    assert len(axis_names) == len(mesh_shape), (axis_names, mesh_shape)
    if kind != "train":
        return _plan_search_serve(cfg, mesh_shape, batch, seq,
                                  axis_names=axis_names, hbm=hbm,
                                  budget=budget, link=link,
                                  peak_flops=peak_flops, hbm_bw=hbm_bw)

    model_size = mesh_shape[axis_names.index("model")]
    has_pod = "pod" in axis_names
    pc = cfg.param_counts()
    param_bytes = _dtype_bytes(cfg.param_dtype)
    fsdp_options: List[Tuple[str, ...]] = [(), ("data",)]
    if has_pod:
        fsdp_options.append(("pod", "data"))
    tp_options = [(model_size, False)] if model_size > 1 else [(1, False)]
    if model_size > 1:
        tp_options.append((1, True))          # dp_over_model: pure DP
    if cfg.moe is not None:
        ep_modes = ["shard", "a2a"] if cfg.moe.n_experts % model_size == 0 \
            and cfg.moe.n_experts >= model_size else ["shard"]
    else:
        ep_modes = ["none"]

    ranked: List[RankedPlan] = []
    for fsdp_axes in fsdp_options:
        for tp, dpom in tp_options:
            for ep_mode in ep_modes:
                if dpom and ep_mode == "a2a":
                    continue                  # a2a routes over the model axis
                # with FSDP storage the reduction IS a reduce-scatter (the
                # scatter specs are the param specs) — only the replicated
                # layout has a genuine all-reduce vs zero choice
                grads = ["reduce_scatter_zero"] if fsdp_axes \
                    else ["all_reduce", "reduce_scatter_zero"]
                for grad in grads:
                    for remat in ("none", "full"):
                        for opt_dtype in ("float32", "bfloat16"):
                            p = ParallelPlan(
                                mesh_shape=mesh_shape, axis_names=axis_names,
                                fsdp_axes=fsdp_axes, tp=tp, ep_mode=ep_mode,
                                dp_over_model=dpom, grad=grad, remat=remat,
                                opt_state_dtype=opt_dtype)
                            if p.dp < 2 and grad == "reduce_scatter_zero":
                                continue      # nothing to scatter over
                            ranked.append(_score_train(
                                cfg, p, pc, batch, seq, param_bytes,
                                hbm * budget, link, peak_flops, hbm_bw))
    feas = sorted((r for r in ranked if r.feasible),
                  key=lambda r: (r.total_s, r.plan.label()))
    infeas = sorted((r for r in ranked if not r.feasible),
                    key=lambda r: (r.memory["total"], r.plan.label()))
    return feas + infeas


def _score_train(cfg: ModelConfig, plan: ParallelPlan, pc: dict, batch: int,
                 seq: int, param_bytes: int, hbm_budget: float,
                 link: LinkClass, peak_flops: float,
                 hbm_bw: float) -> RankedPlan:
    # ceil-div: a batch the dp group doesn't divide leaves some chips with a
    # padded row (mirrors make_cell_ctx dropping non-dividing axes) — scored
    # approximately rather than filtered, so the list is never empty
    batch_local = max(1, math.ceil(batch / plan.dp))
    act = costmodel.train_activation_bytes(
        batch_local, seq, cfg.d_model, max(cfg.d_ff // plan.tp, 1),
        cfg.n_layers, max(cfg.vocab // plan.tp, 1), remat=plan.remat)
    mem = costmodel.train_memory_bytes(
        pc["total"], tp=plan.tp, fsdp_shard=plan.fsdp_shard, dp=plan.dp,
        grad=plan.grad, param_bytes=param_bytes,
        grad_bytes=_dtype_bytes(plan.grad_dtype),
        opt_state_bytes=_dtype_bytes(plan.opt_state_dtype),
        master=plan.master_weights, activation_bytes=act)
    cost = costmodel.train_step_cost(
        pc["active"], pc["total"], tokens=float(batch) * seq,
        chips=plan.chips, tp=plan.tp, dp=plan.dp,
        fsdp_shard=plan.fsdp_shard, grad=plan.grad, batch_local=batch_local,
        seq=seq, d_model=cfg.d_model, n_layers=cfg.n_layers,
        param_bytes=2,                        # gathers/streams run in bf16
        grad_bytes=_dtype_bytes(plan.grad_dtype),
        opt_state_bytes=_dtype_bytes(plan.opt_state_dtype),
        master=plan.master_weights, remat=plan.remat, link=link,
        peak_flops=peak_flops, hbm_bw=hbm_bw)
    ep_s = _ep_cost(cfg, plan, batch_local, seq, link)
    cost = dict(cost, ep_s=ep_s, total_s=cost["total_s"] + ep_s)
    return RankedPlan(plan=plan, cost=cost, memory=mem,
                      feasible=mem["total"] <= hbm_budget)


def _plan_search_serve(cfg: ModelConfig, mesh_shape, batch, seq, *,
                       axis_names, hbm, budget, link, peak_flops,
                       hbm_bw) -> List[RankedPlan]:
    """Serving lattice (much smaller: no grads/optimizer): params bf16,
    TP-resident when the shard fits (no per-token FSDP gathers), FSDP
    storage otherwise; scored with ``costmodel.decode_step_cost``."""
    chips = math.prod(mesh_shape)
    model_size = mesh_shape[axis_names.index("model")]
    total = cfg.param_counts()["total"]
    has_pod = "pod" in axis_names
    ranked: List[RankedPlan] = []
    for fsdp_axes in ([(), ("data",)] + ([("pod", "data")] if has_pod else [])):
        plan = ParallelPlan(mesh_shape=mesh_shape, axis_names=axis_names,
                            fsdp_axes=fsdp_axes, tp=model_size,
                            ep_mode="none", grad="none", remat="none",
                            opt_state_dtype="float32")
        shard = plan.tp * plan.fsdp_shard
        p_dev = total * 2.0 / shard
        mem = {"params": p_dev, "grads": 0.0, "opt": 0.0,
               "activations": 0.0, "total": p_dev}
        cost = costmodel.decode_step_cost(
            cfg.param_counts()["active"], batch, chips=chips,
            peak_flops=peak_flops, hbm_bw=hbm_bw)
        if fsdp_axes:
            # per-token param regather over the fsdp axes — the reason
            # TP-resident wins whenever the shard fits
            gather = costmodel.t_all_gather(total * 2.0 / shard,
                                            plan.fsdp_shard, link)
            cost = dict(cost, gather_s=gather, comm_s=gather,
                        total_s=cost["total_s"] + gather)
        else:
            cost = dict(cost, gather_s=0.0, comm_s=0.0)
        # TP-resident needs comfortable headroom for the KV cache: the old
        # rule table's 12 GiB line, kept as ¾ of the budgeted HBM
        limit = hbm * budget * (5.0 / 6.0 if not fsdp_axes else 1.0)
        ranked.append(RankedPlan(plan=plan, cost=cost, memory=mem,
                                 feasible=p_dev < limit))
    feas = sorted((r for r in ranked if r.feasible),
                  key=lambda r: (r.total_s, r.plan.label()))
    infeas = sorted((r for r in ranked if not r.feasible),
                    key=lambda r: (r.memory["total"], r.plan.label()))
    return feas + infeas


def default_plan(arch: str, kind: str, *, multi_pod: bool = False) -> ParallelPlan:
    """The plan the cost model picks for an (arch × shape-kind) cell on the
    production mesh — the replacement for the old hand-written
    ``dryrun.default_pcfg`` rule table."""
    from repro import configs
    from repro.config import SHAPES
    cfg = configs.get(arch)
    mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
    shape = SHAPES["train_4k" if kind == "train" else
                   ("prefill_32k" if kind == "prefill" else "decode_32k")]
    ranked = plan_search(cfg, mesh_shape, shape.global_batch, shape.seq_len,
                         kind)
    return best_plan(ranked)


def best_plan(ranked: List[RankedPlan]) -> ParallelPlan:
    """Head of a ranked lattice with the numerics guard the time model
    can't see: bf16 moments only buy HBM bytes, so keep f32 optimizer
    states unless no f32 point fits."""
    for r in ranked:
        if r.feasible and r.plan.opt_state_dtype == "float32":
            return r.plan
    return ranked[0].plan


def format_plan_table(ranked: List[RankedPlan], top: int = 12) -> str:
    """Markdown table of the ranked lattice (``roofline --plan``)."""
    rows = ["| # | plan | mem/dev GiB | fits | compute_s | comm_s | "
            "update_s | total_s |",
            "|---|---|---|---|---|---|---|---|"]
    for i, r in enumerate(ranked[:top]):
        c = r.cost
        rows.append(
            f"| {i + 1} | {r.plan.label()} | "
            f"{r.memory['total'] / 2**30:.2f} | "
            f"{'y' if r.feasible else 'OOM'} | {c['compute_s']:.4f} | "
            f"{c.get('comm_s', 0) + c.get('ep_s', 0):.4f} | "
            f"{c.get('update_s', 0):.4f} | {c['total_s']:.4f} |")
    return "\n".join(rows)
