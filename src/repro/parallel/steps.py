"""Train / prefill / decode step builders (pjit programs).

Each builder returns a pure function plus its (in/out) sharding trees so the
same object serves the real launcher and the dry-run's
``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import transformer as T
from repro.models import encdec as E
from repro.models.moe import MeshCtx
from repro import optim
from .sharding import (param_specs, opt_specs, scatter_specs, to_shardings,
                       batch_spec)

Pytree = Any


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, *,
                  z_loss: float = 0.0, chunk: Optional[int] = None) -> jax.Array:
    """Token-mean CE over (B, S, V) f32 logits; vocab may be model-sharded —
    the label pick uses an iota-mask reduction (shardable, no gather)."""

    def _ce(lg, lb):
        lg = lg.astype(jnp.float32)
        m = jnp.max(lg, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
        vocab_iota = lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
        picked = jnp.sum(jnp.where(vocab_iota == lb[..., None], lg, 0.0), axis=-1)
        loss = lse - picked
        if z_loss:
            loss = loss + z_loss * lse ** 2
        return jnp.sum(loss), loss.size

    if chunk is None:
        total, n = _ce(logits, labels)
        return total / n
    # sequence-chunked CE (bounds the (B, Sc, V) f32 transient); pad the
    # remainder with an ignored label (-1 never matches the vocab iota and
    # its lse contribution is subtracted via the weight mask)
    s = logits.shape[1]
    pad = (-s) % chunk
    if pad:
        logits = jnp.pad(logits, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    sp = s + pad
    lg = logits.reshape(logits.shape[0], sp // chunk, chunk, -1)
    lb = labels.reshape(labels.shape[0], sp // chunk, chunk)

    def body(acc, xs):
        lgc, lbc = xs
        lgf = lgc.astype(jnp.float32)
        m = jnp.max(lgf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lgf - m), axis=-1)) + m[..., 0]
        iota = lax.broadcasted_iota(jnp.int32, lgf.shape, lgf.ndim - 1)
        picked = jnp.sum(jnp.where(iota == lbc[..., None], lgf, 0.0), axis=-1)
        w = (lbc >= 0).astype(jnp.float32)
        return acc + jnp.sum((lse - picked) * w), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32),
                        (jnp.moveaxis(lg, 1, 0), jnp.moveaxis(lb, 1, 0)))
    return total / labels_size_orig(labels, pad)


def labels_size_orig(padded_labels, pad):
    b, sp = padded_labels.shape
    return b * (sp - pad)


def make_loss_fn(cfg: ModelConfig, pcfg: ParallelConfig, tcfg: TrainConfig,
                 ctx: Optional[MeshCtx]):
    def loss_fn(params, batch):
        if cfg.enc_dec:
            logits, aux = E.forward(params, batch["frames"], batch["tokens"], cfg,
                                    remat=pcfg.remat, ctx=ctx,
                                    unroll=pcfg.scan_unroll)
        else:
            logits, aux = T.forward(params, batch["tokens"], cfg, ctx=ctx,
                                    remat=pcfg.remat, unroll=pcfg.scan_unroll)
        if ctx is not None:
            vpart = None if getattr(ctx, "dp_over_model", False) else "model"
            logits = lax.with_sharding_constraint(
                logits, NamedSharding(ctx.mesh, P(ctx.batch_axes, None, vpart)))
        loss = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:],
                             z_loss=tcfg.z_loss, chunk=pcfg.logit_chunk)
        loss = loss + 1e-2 * aux  # MoE load-balance
        return loss, {"loss": loss, "aux": aux}
    return loss_fn


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig, tcfg: TrainConfig,
                    ctx: Optional[MeshCtx]) -> Callable:
    """Train step for a layout: dispatches on ``pcfg.grad_reduce`` — the
    classic all-reduce step, or the ZeRO reduce-scatter step when a mesh ctx
    is available to scatter over."""
    if pcfg.grad_reduce == "reduce_scatter_zero":
        if ctx is not None:
            return make_train_step_zero(cfg, pcfg, tcfg, ctx)
        import warnings
        warnings.warn("grad_reduce='reduce_scatter_zero' needs a mesh ctx; "
                      "falling back to the single-device all-reduce step",
                      stacklevel=2)
    loss_fn = make_loss_fn(cfg, pcfg, tcfg, ctx)

    def train_step(state: Pytree, batch: Pytree) -> Tuple[Pytree, Pytree]:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        if pcfg.grad_barrier:
            # pin the gradient reductions in their native (bf16) dtype: the
            # barrier stops XLA from sinking the all-reduce past the f32
            # converts of the optimizer math (§Perf A6)
            grads = lax.optimization_barrier(grads)
        if pcfg.grad_dtype != "float32":
            grads = jax.tree.map(lambda g: g.astype(pcfg.grad_dtype), grads)
        grads, gnorm = optim.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = optim.warmup_cosine(state["opt"]["step"], lr=tcfg.lr,
                                 warmup_steps=tcfg.warmup_steps,
                                 total_steps=tcfg.total_steps)
        params, opt_state = optim.adamw_update(
            grads, state["opt"], state["params"], lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": params, "opt": opt_state}, metrics

    return train_step


def make_train_step_zero(cfg: ModelConfig, pcfg: ParallelConfig,
                         tcfg: TrainConfig, ctx: MeshCtx) -> Callable:
    """ZeRO train step: grads reduce-scattered over the fsdp (else data)
    axes, AdamW updates only the local shard, params all-gathered for the
    next forward (``optim.adamw_update_zero``).

    Loss/grad/clip are token-for-token the all-reduce step — the clip norm
    is taken on the reduced grads *before* the scatter so the two steps'
    trajectories coincide; only the layout of the optimizer segment (and
    hence its comm pattern: Θ(m·(p-1)/p) reduce-scatter + all-gather instead
    of the Θ(2m·(p-1)/p) all-reduce feeding p redundant full updates)
    differs."""
    if ctx is None:
        raise ValueError("make_train_step_zero needs a mesh ctx to scatter "
                         "over; use make_train_step on a single device")
    loss_fn = make_loss_fn(cfg, pcfg, tcfg, ctx)

    def train_step(state: Pytree, batch: Pytree) -> Tuple[Pytree, Pytree]:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        if pcfg.grad_barrier:
            grads = lax.optimization_barrier(grads)
        if pcfg.grad_dtype != "float32":
            grads = jax.tree.map(lambda g: g.astype(pcfg.grad_dtype), grads)
        grads, gnorm = optim.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = optim.warmup_cosine(state["opt"]["step"], lr=tcfg.lr,
                                 warmup_steps=tcfg.warmup_steps,
                                 total_steps=tcfg.total_steps)
        scatter = to_shardings(scatter_specs(state["params"], cfg, ctx),
                               ctx.mesh)
        gather = to_shardings(param_specs(state["params"], cfg, ctx), ctx.mesh)
        params, opt_state = optim.adamw_update_zero(
            grads, state["opt"], state["params"], scatter=scatter,
            gather=gather, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": params, "opt": opt_state}, metrics

    return train_step


def init_train_state(rng, cfg: ModelConfig, pcfg: ParallelConfig) -> Pytree:
    init = E.init if cfg.enc_dec else T.init
    params = init(rng, cfg)
    opt = optim.adamw_init(params, pcfg.opt_state_dtype,
                           master=pcfg.master_weights)
    if pcfg.master_weights:
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    return {"params": params, "opt": opt}


def abstract_train_state(cfg: ModelConfig, pcfg: ParallelConfig) -> Pytree:
    return jax.eval_shape(partial(init_train_state, cfg=cfg, pcfg=pcfg),
                          jax.random.PRNGKey(0))


def train_state_shardings(cfg: ModelConfig, pcfg: ParallelConfig,
                          ctx: MeshCtx, state: Pytree) -> Pytree:
    pspec = param_specs(state["params"], cfg, ctx)
    sspec = scatter_specs(state["params"], cfg, ctx) \
        if pcfg.grad_reduce == "reduce_scatter_zero" else None
    ospec = opt_specs(pspec, sspec)
    if "master" in state["opt"]:
        ospec["master"] = sspec if sspec is not None else pspec
    tree = {"params": pspec, "opt": ospec}
    return to_shardings(tree, ctx.mesh)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                      ctx: Optional[MeshCtx]) -> Callable:
    """Fused prefill: one cache-writing full-sequence forward per prompt —
    ``prefill(params, batch, cache)`` returns ``(last_logits, cache)``
    (enc-dec additionally returns the encoder output the decode steps need).
    ``batch`` may carry per-row true prompt ``length``s for right-padded
    prompts (attention patterns only; pad entries are causally invisible)."""

    def prefill(params, batch, cache):
        length = batch.get("length")
        if cfg.enc_dec:
            enc = E.encode(params, batch["frames"], cfg, remat="none", ctx=ctx,
                           unroll=pcfg.scan_unroll)
            logits, cache = E.decode_prefill(params, batch["tokens"], enc, cache,
                                             cfg, length=length, ctx=ctx,
                                             unroll=pcfg.scan_unroll)
            return logits, cache, enc
        logits, cache = T.prefill(params, batch["tokens"], cache, cfg,
                                  length=length, ctx=ctx,
                                  unroll=pcfg.scan_unroll)
        return logits, cache

    return prefill


def make_decode_step(cfg: ModelConfig, pcfg: ParallelConfig,
                     ctx: Optional[MeshCtx], *,
                     return_logits: bool = False,
                     paged: bool = False) -> Callable:
    """Decode step: greedy (argmax token) by default; ``return_logits``
    hands back the f32 logits instead so the scheduler can sample
    (temperature / top-p) in its slot loop.  ``paged``: the step takes
    ``(params, tok, cache, pos, block_tables)`` — the cache is the shared
    page arena and every request reads/writes through its table row, so the
    paged and end-aligned modes share one fixed-shape engine."""
    if paged and cfg.enc_dec:
        raise NotImplementedError("paged decode is decoder-only")

    def decode(params, token, cache, pos, enc_out=None):
        if cfg.enc_dec:
            logit, new_cache = E.decode_step(params, token, cache, pos, enc_out, cfg,
                                             unroll=pcfg.scan_unroll, ctx=ctx)
        else:
            logit, new_cache = T.decode_step(params, token, cache, pos, cfg, ctx=ctx,
                                             unroll=pcfg.scan_unroll)
        if return_logits:
            return logit.astype(jnp.float32), new_cache
        return jnp.argmax(logit, axis=-1).astype(jnp.int32), new_cache

    def decode_paged(params, token, cache, pos, block_tables):
        logit, new_cache = T.decode_step(params, token, cache, pos, cfg,
                                         ctx=ctx, unroll=pcfg.scan_unroll,
                                         block_tables=block_tables)
        if return_logits:
            return logit.astype(jnp.float32), new_cache
        return jnp.argmax(logit, axis=-1).astype(jnp.int32), new_cache

    return decode_paged if paged else decode


def make_chunk_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                            ctx: Optional[MeshCtx]) -> Callable:
    """Chunked-prefill step for the paged engine: one fixed-shape (1, chunk)
    slice of one request's prompt per call — K/V written into freshly
    allocated pages through the block table, ``(last_logits, cache)`` back
    (``models.transformer.prefill_paged``).  Fixed chunk shape means ONE
    compile regardless of prompt length, and the per-call cost bounds the
    stall any admission can inflict on in-flight decodes
    (``costmodel.chunked_prefill_cost``)."""
    if cfg.enc_dec:
        raise NotImplementedError("chunked prefill is decoder-only")

    def chunk_prefill(params, tokens, cache, pos0, block_tables, length):
        return T.prefill_paged(params, tokens, cache, cfg, pos0=pos0,
                               block_tables=block_tables, length=length,
                               ctx=ctx, unroll=pcfg.scan_unroll)

    return chunk_prefill
