"""Sharding rules: parameter-name → PartitionSpec, driven by the Table-1 cost
model's layout conventions (DP/FSDP over 'data' (+'pod'), TP/EP over 'model').

Rules are path-based: the last path components of each leaf select a template.
Templates use the symbols:
  IN   (d_in, d_out) weight:  P(fsdp, 'model')   — column-parallel
  OUT  (d_out, d_in) weight:  P('model', fsdp)   — row-parallel
  EP_IN/EP_OUT             : expert tensors (layout depends on n_experts vs ep)
  REP                      : replicated
Stacked (scanned) parameters get a leading ``None`` automatically by rank.
"""
from __future__ import annotations

import warnings
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig
from repro.models.moe import MeshCtx

Pytree = Any


def make_ctx(mesh: Mesh, parallel) -> MeshCtx:
    """MeshCtx from a layout — a ``ParallelConfig`` or a first-class
    ``planner.ParallelPlan`` (bridged via ``to_pcfg``)."""
    if hasattr(parallel, "to_pcfg"):
        parallel = parallel.to_pcfg()
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    if parallel.dp_over_model:
        batch_axes += ("model",)
    fsdp: Tuple[str, ...] = ()
    if parallel.fsdp_params:
        fsdp = ("data",)
        if parallel.fsdp_pod and "pod" in axes:
            fsdp = ("pod", "data")
    return MeshCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                   fsdp_axes=fsdp, moe_a2a_ep=parallel.moe_a2a_ep,
                   engine_replicate=parallel.engine_replicate,
                   seq_parallel=parallel.sequence_parallel,
                   foopar_tp=parallel.use_foopar_tp,
                   manual_attention=parallel.manual_attention,
                   dp_over_model=parallel.dp_over_model)


def batch_spec(ctx: MeshCtx, ndim: int, batch_dim: int = 0) -> P:
    parts = [None] * ndim
    parts[batch_dim] = ctx.batch_axes
    return P(*parts)


# ---------------------------------------------------------------------------
# rule table
# ---------------------------------------------------------------------------
_IN_NAMES = {"wq", "wk", "wv", "w_gate", "w_up", "up_proj", "w_in", "in_proj",
             "w_gates", "unembed"}
_OUT_NAMES = {"wo", "w_down", "down_proj", "out_proj", "proj"}
_REP_NAMES = {"scale", "bias", "router", "A_log", "D", "dt_bias",
              "enc_pos", "dec_pos"}


def _leaf_spec(path: Tuple[str, ...], leaf, cfg: ModelConfig, ctx: MeshCtx,
               use_ep: bool) -> P:
    name = path[-1]
    parents = set(path[:-1])
    fsdp = ctx.fsdp_axes if ctx.fsdp_axes else None
    model = ctx.model_axis

    def with_stack(spec_dims):
        pad = leaf.ndim - len(spec_dims)
        return P(*([None] * pad + spec_dims))

    if "shared" in parents:  # MoE shared expert: must match moe_ffn in_specs
        if name in ("w_gate", "w_up"):
            return with_stack([None, model])
        if name == "w_down":
            return with_stack([model, None])

    if "moe" in parents and name in ("w_gate", "w_up", "w_down"):
        if ctx.moe_a2a_ep:
            if name == "w_down":                    # (E, ff, d)
                return with_stack(["data", model, None])
            return with_stack(["data", None, model])  # (E, d, ff)
        if use_ep:
            if name == "w_down":                    # (E, ff, d)
                return with_stack([model, None, fsdp])
            return with_stack([model, fsdp, None])  # (E, d, ff)
        else:
            if name == "w_down":
                return with_stack([None, model, fsdp])
            return with_stack([None, fsdp, model])

    if getattr(ctx, "engine_replicate", False) and \
            parents & {"mlstm", "slstm", "mamba"}:
        # §Perf C6: recurrent blocks run batch-parallel only — weights keep
        # FSDP storage sharding but no TP (local matmuls, zero act collectives)
        if name in _IN_NAMES | {"conv_w"}:
            return with_stack([fsdp, None] if name != "conv_w" else [None, None])
        if name in _OUT_NAMES:
            return with_stack([None, fsdp])
        return P(*([None] * leaf.ndim))

    if name == "embedding":                          # (V, d)
        return with_stack([model, fsdp])
    if name == "conv_w":                             # (W, C)
        return with_stack([None, model])
    if name in _REP_NAMES:
        return P(*([None] * leaf.ndim))
    if name == "wq" and "mlstm" in parents:
        return with_stack([fsdp, model])
    if name in _IN_NAMES:
        return with_stack([fsdp, model])
    if name in _OUT_NAMES:
        return with_stack([model, fsdp])
    # default: replicate (and surface it for review)
    return P(*([None] * leaf.ndim))


# Partitions silently dropped by ``sanitize_spec`` make the realized layout
# diverge from what the rule table (and the planner's cost predictions)
# assumed — so every drop is counted here and surfaced: once as a warning,
# and in full in the dry-run report (``dropped_partition_report``).
_DROPPED: dict = {}
_WARNED = [False]


def reset_dropped_partitions() -> None:
    _DROPPED.clear()


def dropped_partition_report() -> list:
    """Partitions dropped since the last reset: one record per (leaf, dim)
    whose rule-table axes didn't divide the dim."""
    return [dict(leaf=k[0], dim=k[1], **v) for k, v in sorted(_DROPPED.items())]


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh,
                  path: Optional[str] = None) -> P:
    """Drop partitions on dims the mesh axes don't divide evenly (jit
    in_shardings require exact divisibility, unlike constraints).  Each drop
    is recorded (warn once + dry-run report) so planner predictions can't
    silently diverge from the realized layout."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for i, (dim, part) in enumerate(zip(shape, parts)):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size == 0:
            out.append(part)
            continue
        out.append(None)
        _DROPPED[(path or "<anon>", i)] = {
            "shape": tuple(shape), "axes": tuple(axes), "shard": size}
        if not _WARNED[0]:
            _WARNED[0] = True
            warnings.warn(
                f"sharding: dropped partition {axes} on dim {i} of "
                f"{path or shape} ({dim} % {size} != 0) — the leaf stays "
                "replicated on that dim; see dropped_partition_report() "
                "for the full list", stacklevel=2)
    return P(*out)


def param_specs(params: Pytree, cfg: ModelConfig, ctx: MeshCtx) -> Pytree:
    """PartitionSpec tree mirroring ``params``."""
    use_ep = bool(cfg.moe) and cfg.moe.n_experts % ctx.model_size == 0 \
        and cfg.moe.n_experts >= ctx.model_size

    def strip_model(spec):
        if not getattr(ctx, "dp_over_model", False):
            return spec
        parts = []
        for part in spec:
            if part == ctx.model_axis:
                parts.append(None)
            elif isinstance(part, tuple):
                parts.append(tuple(a for a in part if a != ctx.model_axis) or None)
            else:
                parts.append(part)
        return P(*parts)

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = strip_model(_leaf_spec(names, leaf, cfg, ctx, use_ep))
        return sanitize_spec(spec, leaf.shape, ctx.mesh, path="/".join(names))

    return jax.tree_util.tree_map_with_path(visit, params)


def scatter_specs(params: Pytree, cfg: ModelConfig, ctx: MeshCtx) -> Pytree:
    """ZeRO grad/optimizer layout: each leaf's param spec with the scatter
    axes (the fsdp axes, else the batch axes — the grad-reduction group,
    which includes 'model' under dp_over_model) added on the first free dim
    they divide.  Leaves already sharded over a scatter axis (FSDP param
    storage) and leaves with no divisible free dim keep their param spec —
    those gradients stay all-reduced."""
    axes = ctx.fsdp_axes or ctx.batch_axes
    base = param_specs(params, cfg, ctx)
    if not axes:
        return base
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    part = axes if len(axes) > 1 else axes[0]

    def scatter(spec, leaf):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        used = set()
        for p_ in parts:
            used.update(p_ if isinstance(p_, tuple) else (p_,))
        if used & set(axes):
            return spec                      # FSDP already scatters this leaf
        for i, (dim, p_) in enumerate(zip(leaf.shape, parts)):
            if p_ is None and dim % size == 0 and dim >= size:
                parts[i] = part
                return P(*parts)
        return spec

    return jax.tree.map(scatter, base, params,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shard_params(params: Pytree, cfg: ModelConfig, ctx: MeshCtx) -> Pytree:
    """Device-put params according to the rules (for real runs; the dry-run
    only ever uses the specs)."""
    shardings = to_shardings(param_specs(params, cfg, ctx), ctx.mesh)
    return jax.device_put(params, shardings)


def opt_specs(param_spec_tree: Pytree,
              scatter_spec_tree: Optional[Pytree] = None) -> Pytree:
    """Optimizer state specs: m/v mirror params — or, under the ZeRO
    reduce-scatter strategy, the ``scatter_specs`` layout (each device keeps
    only the moment shard it updates); step replicated."""
    sp = scatter_spec_tree if scatter_spec_tree is not None else param_spec_tree
    return {"m": sp, "v": sp, "step": P()}
