"""Sharding rules: parameter-name → PartitionSpec, driven by the Table-1 cost
model's layout conventions (DP/FSDP over 'data' (+'pod'), TP/EP over 'model').

Rules are path-based: the last path components of each leaf select a template.
Templates use the symbols:
  IN   (d_in, d_out) weight:  P(fsdp, 'model')   — column-parallel
  OUT  (d_out, d_in) weight:  P('model', fsdp)   — row-parallel
  EP_IN/EP_OUT             : expert tensors (layout depends on n_experts vs ep)
  REP                      : replicated
Stacked (scanned) parameters get a leading ``None`` automatically by rank.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig
from repro.models.moe import MeshCtx

Pytree = Any


def make_ctx(mesh: Mesh, parallel: ParallelConfig) -> MeshCtx:
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    if parallel.dp_over_model:
        batch_axes += ("model",)
    fsdp: Tuple[str, ...] = ()
    if parallel.fsdp_params:
        fsdp = ("data",)
        if parallel.fsdp_pod and "pod" in axes:
            fsdp = ("pod", "data")
    return MeshCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                   fsdp_axes=fsdp, moe_a2a_ep=parallel.moe_a2a_ep,
                   engine_replicate=parallel.engine_replicate,
                   seq_parallel=parallel.sequence_parallel,
                   foopar_tp=parallel.use_foopar_tp,
                   manual_attention=parallel.manual_attention,
                   dp_over_model=parallel.dp_over_model)


def batch_spec(ctx: MeshCtx, ndim: int, batch_dim: int = 0) -> P:
    parts = [None] * ndim
    parts[batch_dim] = ctx.batch_axes
    return P(*parts)


# ---------------------------------------------------------------------------
# rule table
# ---------------------------------------------------------------------------
_IN_NAMES = {"wq", "wk", "wv", "w_gate", "w_up", "up_proj", "w_in", "in_proj",
             "w_gates", "unembed"}
_OUT_NAMES = {"wo", "w_down", "down_proj", "out_proj", "proj"}
_REP_NAMES = {"scale", "bias", "router", "A_log", "D", "dt_bias",
              "enc_pos", "dec_pos"}


def _leaf_spec(path: Tuple[str, ...], leaf, cfg: ModelConfig, ctx: MeshCtx,
               use_ep: bool) -> P:
    name = path[-1]
    parents = set(path[:-1])
    fsdp = ctx.fsdp_axes if ctx.fsdp_axes else None
    model = ctx.model_axis

    def with_stack(spec_dims):
        pad = leaf.ndim - len(spec_dims)
        return P(*([None] * pad + spec_dims))

    if "shared" in parents:  # MoE shared expert: must match moe_ffn in_specs
        if name in ("w_gate", "w_up"):
            return with_stack([None, model])
        if name == "w_down":
            return with_stack([model, None])

    if "moe" in parents and name in ("w_gate", "w_up", "w_down"):
        if ctx.moe_a2a_ep:
            if name == "w_down":                    # (E, ff, d)
                return with_stack(["data", model, None])
            return with_stack(["data", None, model])  # (E, d, ff)
        if use_ep:
            if name == "w_down":                    # (E, ff, d)
                return with_stack([model, None, fsdp])
            return with_stack([model, fsdp, None])  # (E, d, ff)
        else:
            if name == "w_down":
                return with_stack([None, model, fsdp])
            return with_stack([None, fsdp, model])

    if getattr(ctx, "engine_replicate", False) and \
            parents & {"mlstm", "slstm", "mamba"}:
        # §Perf C6: recurrent blocks run batch-parallel only — weights keep
        # FSDP storage sharding but no TP (local matmuls, zero act collectives)
        if name in _IN_NAMES | {"conv_w"}:
            return with_stack([fsdp, None] if name != "conv_w" else [None, None])
        if name in _OUT_NAMES:
            return with_stack([None, fsdp])
        return P(*([None] * leaf.ndim))

    if name == "embedding":                          # (V, d)
        return with_stack([model, fsdp])
    if name == "conv_w":                             # (W, C)
        return with_stack([None, model])
    if name in _REP_NAMES:
        return P(*([None] * leaf.ndim))
    if name == "wq" and "mlstm" in parents:
        return with_stack([fsdp, model])
    if name in _IN_NAMES:
        return with_stack([fsdp, model])
    if name in _OUT_NAMES:
        return with_stack([model, fsdp])
    # default: replicate (and surface it for review)
    return P(*([None] * leaf.ndim))


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop partitions on dims the mesh axes don't divide evenly (jit
    in_shardings require exact divisibility, unlike constraints)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(part if dim % size == 0 else None)
    return P(*out)


def param_specs(params: Pytree, cfg: ModelConfig, ctx: MeshCtx) -> Pytree:
    """PartitionSpec tree mirroring ``params``."""
    use_ep = bool(cfg.moe) and cfg.moe.n_experts % ctx.model_size == 0 \
        and cfg.moe.n_experts >= ctx.model_size

    def strip_model(spec):
        if not getattr(ctx, "dp_over_model", False):
            return spec
        parts = []
        for part in spec:
            if part == ctx.model_axis:
                parts.append(None)
            elif isinstance(part, tuple):
                parts.append(tuple(a for a in part if a != ctx.model_axis) or None)
            else:
                parts.append(part)
        return P(*parts)

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = strip_model(_leaf_spec(names, leaf, cfg, ctx, use_ep))
        return sanitize_spec(spec, leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(visit, params)


def to_shardings(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shard_params(params: Pytree, cfg: ModelConfig, ctx: MeshCtx) -> Pytree:
    """Device-put params according to the rules (for real runs; the dry-run
    only ever uses the specs)."""
    shardings = to_shardings(param_specs(params, cfg, ctx), ctx.mesh)
    return jax.device_put(params, shardings)


def opt_specs(param_spec_tree: Pytree) -> Pytree:
    """Optimizer state specs: m/v mirror params; step replicated."""
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}
