from .recovery import TrainingRunner, StepWatchdog, ElasticPlan
