"""Fault tolerance & elasticity for 1000+-node synchronous SPMD training.

Policy (DESIGN.md §5):

* **Checkpoint/restart** is the recovery primitive.  Steps are fenced by
  atomic checkpoint commits (checkpoint/store.py); the data pipeline is a
  pure function of (seed, step) (data/pipeline.py) — so a restart resumes
  bitwise-identically from the last commit.  ``TrainingRunner.run`` is a
  crash-only loop: any exception falls back to restore-latest-and-continue,
  bounded by ``max_restarts``.

* **Straggler mitigation**: under synchronous SPMD a straggling *chip* stalls
  the whole step, so mitigation is detect-and-evict, not work-stealing (which
  would break the paper's static process↔data analyzability).  The
  ``StepWatchdog`` tracks a robust step-time estimate (median + MAD); a step
  exceeding ``k`` MADs raises a straggler event, and the runner responds by
  checkpointing and requesting a reschedule (on a real cluster: replace the
  node, here: restart the loop).

* **Elastic scaling**: ``ElasticPlan`` recomputes the mesh for a new chip
  count.  Because params/opt are saved as logical arrays and resharded on
  restore (restore_checkpoint with a new sharding tree), shrinking/growing
  the ``data`` axis needs no format change; the batch iterator re-derives
  per-host slices from global indices.  The ``model`` axis is fixed per
  config (TP degree is architectural), so elasticity acts on data/pod axes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from repro import checkpoint as ckpt


class StepWatchdog:
    """Robust step-time anomaly detector (median + k·MAD)."""

    def __init__(self, k: float = 6.0, window: int = 50, min_steps: int = 10):
        self.k, self.window, self.min_steps = k, window, min_steps
        self.times: List[float] = []

    def observe(self, dt: float) -> bool:
        """Record a step time; returns True if it's a straggler event."""
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) < self.min_steps:
            return False
        med = float(np.median(self.times))
        mad = float(np.median(np.abs(np.array(self.times) - med))) + 1e-9
        return dt > med + self.k * mad


@dataclass
class ElasticPlan:
    """Mesh plan for a given healthy-chip count."""
    model: int = 16
    min_data: int = 1

    def mesh_for(self, n_chips: int, devices=None):
        data = max(self.min_data, n_chips // self.model)
        shape, axes = (data, self.model), ("data", "model")
        if devices is not None:
            devices = devices[: data * self.model]
        return jax.make_mesh(shape, axes, devices=devices)


@dataclass
class TrainingRunner:
    """Crash-only training loop: restore → run → (fault) → restore → ...

    ``build`` re-creates (state, step_fn, batch_iter) from a step index —
    called at start and after every recovery, so device placement and the
    data stream are always reconstructed from durable state only.
    """
    directory: str
    build: Callable[[int], tuple]           # step -> (state, step_fn, batches)
    checkpoint_every: int = 100
    max_restarts: int = 3
    watchdog: StepWatchdog = field(default_factory=StepWatchdog)

    def run(self, total_steps: int, *, inject_fault_at: Optional[int] = None):
        """Returns (final_state, metrics_history).  ``inject_fault_at`` is the
        test hook proving recovery (tests/test_runtime.py)."""
        restarts = 0
        history = []
        saver = ckpt.AsyncCheckpointer(self.directory)
        while True:
            start = ckpt.latest_step(self.directory) or 0
            state, step_fn, batches = self.build(start)
            step = start
            try:
                for batch in batches:
                    if step >= total_steps:
                        saver.wait()
                        return state, history
                    t0 = time.perf_counter()
                    if inject_fault_at is not None and step == inject_fault_at:
                        inject_fault_at = None  # fire once
                        raise RuntimeError("injected node failure")
                    state, metrics = step_fn(state, batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.perf_counter() - t0
                    straggler = self.watchdog.observe(dt)
                    history.append({"step": step, "time_s": dt,
                                    **{k: float(v) for k, v in metrics.items()}})
                    step += 1
                    if step % self.checkpoint_every == 0:
                        saver.save(step, state)
                    if straggler:
                        raise RuntimeError(f"straggler step {step - 1}: {dt:.3f}s")
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                saver.wait()
                # recovery: loop re-enters, restores latest commit, rebuilds
                continue
