"""ChatGLM3-6B [arXiv:2406.12793; hf]: 2d-RoPE (half-dim rotary), GQA kv=2."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024,
    rope_fraction=0.5,
)
