"""Mixtral-8x22B [arXiv:2401.04088; hf]: 8 experts top-2, sliding-window
attention (window 4096) => sub-quadratic decode, long_500k runs with a ring
KV cache."""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    head_dim=128, rope_theta=1000000.0, window=4096,
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    sub_quadratic=True,
    notes="8 experts < 16 model shards => 'tp' expert layout (dropless).",
)
