"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM — VQ image tokens share
the text vocab, so the backbone is a dense LM; frontend stubbed (input_specs
provides token ids).  QK-norm for stability (paper §2)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536,
    head_dim=128, qk_norm=True,
)
