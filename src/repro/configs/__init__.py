"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the ModelConfig; ``ARCHS`` lists all ids;
``cells(name)`` yields the (arch × shape) cells that apply to it
(long_500k only for sub-quadratic archs, per the assignment).
"""
from __future__ import annotations

import importlib
from typing import List

from repro.config import ModelConfig, SHAPES, ShapeConfig

ARCHS = [
    "xlstm-1.3b",
    "llama3.2-3b",
    "command-r-plus-104b",
    "llama3-405b",
    "chatglm3-6b",
    "zamba2-1.2b",
    "chameleon-34b",
    "whisper-base",
    "kimi-k2-1t-a32b",
    "mixtral-8x22b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get(name: str) -> ModelConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG


def shapes_for(name: str) -> List[ShapeConfig]:
    cfg = get(name)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention archs skip 500k decode (DESIGN.md §4)
        out.append(s)
    return out


def cells() -> List[tuple]:
    """All (arch, shape) dry-run cells, including skip markers."""
    out = []
    for a in ARCHS:
        cfg = get(a)
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not cfg.sub_quadratic
            out.append((a, s.name, skip))
    return out
