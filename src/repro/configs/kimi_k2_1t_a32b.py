"""Kimi K2 1T-A32B [arXiv:2501.kimi2, paper table]: trillion-param MoE,
384 experts top-8 + 1 shared expert, expert d_ff=2048."""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
    head_dim=128,
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1),
    notes="1T total / ~32B active; EP=16 over 'model' (24 experts/shard).",
)
