"""Whisper-base [arXiv:2212.04356]: encoder-decoder, conv frontend STUBBED
(input_specs provides (B, 1500, d) frame embeddings)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    enc_dec=True, norm="layernorm", act="gelu", tie_embeddings=True,
)
