"""Zamba2-1.2B [arXiv:2411.15242; hf]: Mamba2 backbone + shared attention
block interleaved (single weight set, applied at two points per period)."""
from repro.config import ModelConfig, SSMConfig

_P = ["mamba2"] * 19
_P[5] = _P[12] = "mamba2_attn"

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000,
    head_dim=64,
    block_pattern=tuple(_P),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True, sub_quadratic=True,
)
