"""Llama-3-405B [arXiv:2407.21783]: GQA, 128k vocab."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    head_dim=128, rope_theta=500000.0,
    notes="Training states need >16GiB/chip on 256 chips; fits at 512 with "
          "ZeRO over pod axis + bf16 optimizer states (see EXPERIMENTS.md).",
)
