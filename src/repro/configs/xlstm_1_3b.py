"""xLSTM-1.3B [arXiv:2405.04517]: sLSTM + mLSTM blocks, 7:1 ratio."""
from repro.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk=256),
    norm="layernorm", tie_embeddings=True, sub_quadratic=True,
    notes="d_ff=0: mLSTM/sLSTM blocks carry their own projections.",
)
