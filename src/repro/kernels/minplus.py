"""(min, +) matrix-product Pallas kernel — the blocked Floyd-Warshall hot spot.

C[i, j] = min_k A[i, k] + B[k, j].  Tropical semiring ⇒ no MXU; this is a VPU
kernel, so the tiling objective is purely memory-hierarchy: stage (bm, bk) and
(bk, bn) tiles in VMEM, keep a running-min accumulator in VMEM, and walk k
innermost.  The inner product is unrolled over the bk dimension in steps of
``uk`` rank-1 (min, +) updates to bound VREG pressure (a full (bm, bk, bn)
broadcast would not fit in VMEM for useful block sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _minplus_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, uk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.inf)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk)
    b = b_ref[...].astype(jnp.float32)  # (bk, bn)
    bk = a.shape[1]

    def body(s, acc):
        # (bm, uk, 1) + (1, uk, bn) -> min over uk
        a_sl = lax.dynamic_slice_in_dim(a, s * uk, uk, axis=1)
        b_sl = lax.dynamic_slice_in_dim(b, s * uk, uk, axis=0)
        upd = jnp.min(a_sl[:, :, None] + b_sl[None, :, :], axis=1)
        return jnp.minimum(acc, upd)

    acc_ref[...] = lax.fori_loop(0, bk // uk, body, acc_ref[...])

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def minplus_pallas(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
                   bk: int = 256, uk: int = 8,
                   interpret: bool = False) -> jax.Array:
    """C = A ⊗ B over the (min, +) semiring, VMEM-tiled."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    uk = min(uk, bk)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % uk == 0
    k_steps = k // bk

    kernel = functools.partial(_minplus_kernel, k_steps=k_steps, uk=uk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
