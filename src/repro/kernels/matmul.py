"""Blocked MXU matmul Pallas kernel — the framework's JBLAS/MKL layer.

Tiling: grid (M/bm, N/bn, K/bk); A tile (bm, bk) and B tile (bk, bn) staged
HBM→VMEM by BlockSpec; f32 accumulator lives in a VMEM scratch across the K
grid dimension (revisited innermost).  Block defaults are MXU-aligned
(multiples of 128 on the matmul dims) and sized so the working set
(bm·bk + bk·bn + bm·bn floats) fits comfortably in ~16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _matmul_acc_kernel(a_ref, b_ref, cin_ref, o_ref, acc_ref, *, k_steps: int,
                       out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = cin_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def matmul_acc_pallas(a: jax.Array, b: jax.Array, c: jax.Array, *,
                      bm: int = 256, bn: int = 256, bk: int = 512,
                      interpret: bool = False) -> jax.Array:
    """C + A @ B accumulated *in place*: the VMEM accumulator initializes
    from the C tile instead of zeros and the C buffer is aliased to the
    output (``input_output_aliases``), so a k-panel loop
    ``c = matmul_acc(a_k, b_k, c)`` updates one (m, n) buffer per step
    rather than materializing a separate A@B product temporary and adding.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n), (a.shape, b.shape, c.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk

    kernel = functools.partial(_matmul_acc_kernel, k_steps=k_steps,
                               out_dtype=c.dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(a, b, c)


def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
                  bk: int = 512, out_dtype=jnp.float32,
                  interpret: bool = False) -> jax.Array:
    """C[m, n] = Σ_k A[m, k] B[k, n], MXU-tiled, f32 VMEM accumulator."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk

    kernel = functools.partial(_matmul_kernel, k_steps=k_steps, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
