"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These define the semantics the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array, *, out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def minplus(a: jax.Array, b: jax.Array) -> jax.Array:
    """(min, +) matrix product: C[i,j] = min_k A[i,k] + B[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) with Hq % Hkv == 0 (GQA).
    ``window``: sliding-window size (keys j with i_abs - j >= window masked);
    query position i is aligned to the *end* of the key sequence (prefill:
    Lq == Lk; decode: Lq == 1 attending to a cache of Lk).
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s *= scale if scale is not None else (1.0 / jnp.sqrt(d))
    lk = k.shape[2]
    qpos = jnp.arange(lq) + (lk - lq)          # query absolute positions
    kpos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
