"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These define the semantics the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array, *, out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def minplus(a: jax.Array, b: jax.Array) -> jax.Array:
    """(min, +) matrix product: C[i,j] = min_k A[i,k] + B[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Paged decode-attention oracle: one query token per request, K/V
    gathered through the block table.

    q: (B, Hkv, rep, hd) — grouped query heads (GQA: rep = Hq // Hkv).
    k_pages, v_pages: (N, block, Hkv, hd) — the shared page arenas.
    block_tables: (B, P) int32 — request b's logical page j lives in
    physical block ``block_tables[b, j]``; -1 marks an unallocated tail
    entry (its keys are masked, the gather clamps the index).
    lengths: (B,) int32 — valid tokens per request (key positions
    >= lengths[b] masked, incl. the partially-filled last page).

    Dtype discipline mirrors ``models.layers._sdpa`` exactly (f32 scores and
    softmax, probabilities cast back to q.dtype for the PV contraction) so
    the paged decode engine's greedy tokens match the end-aligned engine's.
    """
    b, hkv, rep, hd = q.shape
    n, blk, _, _ = k_pages.shape
    p = block_tables.shape[1]
    idx = jnp.maximum(block_tables, 0)                   # clamp -1 entries
    k = k_pages[idx].reshape(b, p * blk, hkv, hd)        # (B, K, Hkv, hd)
    v = v_pages[idx].reshape(b, p * blk, hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", q * scale, k,
                   preferred_element_type=jnp.float32)
    kpos = jnp.arange(p * blk)
    mask = kpos[None, :] < lengths[:, None]              # (B, K)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrk,bkgd->bgrd", probs, v,
                      preferred_element_type=q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) with Hq % Hkv == 0 (GQA).
    ``window``: sliding-window size (keys j with i_abs - j >= window masked);
    query position i is aligned to the *end* of the key sequence (prefill:
    Lq == Lk; decode: Lq == 1 attending to a cache of Lk).
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s *= scale if scale is not None else (1.0 / jnp.sqrt(d))
    lk = k.shape[2]
    qpos = jnp.arange(lq) + (lk - lq)          # query absolute positions
    kpos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
