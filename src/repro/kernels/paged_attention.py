"""Paged decode-attention Pallas kernel (the serving subsystem's hot loop).

One query token per request attends to a KV cache scattered across
fixed-size pages of a shared arena; the request's *block table* names its
pages.  The kernel gathers K/V blocks **through the table** with scalar
prefetch (``pltpu.PrefetchScalarGridSpec``): the table row is available
before the body runs, so each page's BlockSpec ``index_map`` picks the
physical arena block to DMA — the gather costs no extra kernel pass.

Grid (B, Hkv, P): each (request, kv-head) pair owns a run of the innermost
page dimension; the online-softmax statistics (m, l) and the f32 output
accumulator for its ``rep`` grouped query heads persist in VMEM scratch
across pages (the same revisiting pattern as ``flash_attention.py``).
Pages past the request's valid length — and unallocated (-1) table entries
— are skipped whole with ``pl.when`` (the TPU grid is sequential per core,
so the skip saves real time: a request occupying 3 of P=64 table slots pays
for 3 page reads, not 64); the partially-filled last page is masked
per-position.

``paged_attention`` is the public entry: on TPU it lowers the kernel, off
TPU (or if lowering fails) it falls back to the pure-jnp reference in
``ref.py`` — the same auto-dispatch pattern as ``kernels/ops.py``, except
the fallback is the *reference* rather than interpret-mode Pallas, because
the serving engine calls this once per decode tick and interpret-mode
evaluation is a correctness harness, not a serving path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref

try:                                     # pallas needs a recent jaxlib;
    from jax.experimental import pallas as pl            # gate, don't require
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PALLAS = True
except ImportError:                      # pragma: no cover - container has it
    _HAS_PALLAS = False

NEG_INF = -1e30


def _paged_attn_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, pages: int, block: int,
                       scale: float):
    b = pl.program_id(0)
    pg = pl.program_id(2)

    @pl.when(pg == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    base = pg * block
    # whole-page skip: past the valid length, or an unallocated table entry
    live = (base < length) & (tbl_ref[b, pg] >= 0)

    @pl.when(live)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (rep, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (block, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (rep, block)
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)          # partial last page

        m_prev, l_prev = m_ref[...], l_ref[...]           # (rep, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pg == pages - 1)
    def _store():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)   # all pages dead (parked row)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *, scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """Layouts as ``ref.paged_attention``: q (B, Hkv, rep, hd); arenas
    (N, block, Hkv, hd); block_tables (B, P) int32 (-1 = unallocated);
    lengths (B,) int32 valid tokens."""
    b, hkv, rep, hd = q.shape
    n, blk, hkv2, hd2 = k_pages.shape
    assert (hkv, hd) == (hkv2, hd2), (q.shape, k_pages.shape)
    pages = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)

    kernel = functools.partial(_paged_attn_kernel, pages=pages, block=blk,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # (block_tables, lengths)
        grid=(b, hkv, pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda bb, h, p, tbl, lens: (bb, h, 0, 0)),
            # the page gather: the arena block to stage is *named by the
            # prefetched table*, clamped so dead (-1) entries stay in range
            # (their page is skipped in the body)
            pl.BlockSpec((1, blk, 1, hd),
                         lambda bb, h, p, tbl, lens: (jnp.maximum(tbl[bb, p], 0), 0, h, 0)),
            pl.BlockSpec((1, blk, 1, hd),
                         lambda bb, h, p, tbl, lens: (jnp.maximum(tbl[bb, p], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda bb, h, p, tbl, lens: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pages, v_pages)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    backend: str | None = None) -> jax.Array:
    """Auto-dispatched paged decode attention (the model decode path's
    entry).  backend: "pallas" | "ref" | None (auto: pallas on TPU, the
    jnp reference elsewhere — the lowering fallback)."""
    if backend is None:
        backend = "pallas" if (_HAS_PALLAS and
                               jax.default_backend() == "tpu") else "ref"
    if backend == "ref":
        return ref.paged_attention(q, k_pages, v_pages, block_tables, lengths)
    try:
        return paged_attention_pallas(q, k_pages, v_pages, block_tables,
                                      lengths)
    except Exception:                    # lowering/compile failure -> oracle
        return ref.paged_attention(q, k_pages, v_pages, block_tables, lengths)
