"""FlashAttention-style Pallas kernel (online softmax), GQA + causal + SWA.

TPU adaptation of the GPU algorithm: instead of warp-level tiling, the
(bq, d) query tile and the f32 running statistics (m, l, acc) are pinned in
VMEM scratch across the innermost kv-block grid dimension; each step stages a
(bkv, d) K and V tile HBM→VMEM via BlockSpec and performs two MXU matmuls
(S = Q Kᵀ, O += P V).  Fully-masked kv blocks are skipped with ``pl.when``
(the TPU grid is sequential per core, so the skip saves real time, the
analogue of the GPU early-exit).

Layout: q (B, Hq, Lq, D); k, v (B, Hkv, Lk, D); queries are aligned to the
END of the key sequence (prefill Lq == Lk, decode Lq == 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               kv_steps: int, bq: int, bkv: int, lq: int, lk: int,
               scale: float, causal: bool, window: int | None):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions; queries end-aligned to the key sequence
    q_lo = (lk - lq) + iq * bq            # first query position in this tile
    k_lo = jk * bkv

    # block-level skip: causal => newest key in block must be <= newest query
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + bq - 1
    if window is not None:
        live &= q_lo - (k_lo + bkv - 1) < window  # oldest key inside window

    @pl.when(live)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bkv)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(jk == kv_steps - 1)
    def _store():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked row -> 0 output
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None,
                           bq: int = 256, bkv: int = 512,
                           interpret: bool = False) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    rep = hq // hkv
    bq = min(bq, lq)
    bkv = min(bkv, lk)
    assert lq % bq == 0 and lk % bkv == 0
    kv_steps = lk // bkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _fa_kernel, kv_steps=kv_steps, bq=bq, bkv=bkv, lq=lq, lk=lk,
        scale=scale, causal=causal, window=window)

    grid = (b, hq, lq // bq, kv_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, i, j, rep=rep: (bb, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, i, j, rep=rep: (bb, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
