"""Jit'd public wrappers for the Pallas kernels, with CPU-interpret fallback.

On the CPU container the kernels execute under ``interpret=True`` (Python
evaluation of the kernel body — the correctness target); on TPU the same
calls compile to Mosaic.  ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .matmul import matmul_acc_pallas, matmul_pallas
from .minplus import minplus_pallas
from .flash_attention import flash_attention_pallas
from .paged_attention import paged_attention_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def matmul(a, b, *, bm=256, bn=256, bk=512, out_dtype=jnp.float32,
           interpret: bool | None = None):
    return matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                         interpret=_auto_interpret(interpret))


def matmul_acc(a, b, c, *, bm=256, bn=256, bk=512,
               interpret: bool | None = None):
    """In-place ``c + a @ b`` (c's buffer is aliased to the output — donate
    c under jit, i.e. never reuse it after the call)."""
    return matmul_acc_pallas(a, b, c, bm=bm, bn=bn, bk=bk,
                             interpret=_auto_interpret(interpret))


def minplus(a, b, *, bm=256, bn=256, bk=256, uk=8, interpret: bool | None = None):
    return minplus_pallas(a, b, bm=bm, bn=bn, bk=bk, uk=uk,
                          interpret=_auto_interpret(interpret))


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=256, bkv=512, interpret: bool | None = None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, bq=bq, bkv=bkv,
                                  interpret=_auto_interpret(interpret))


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, interpret: bool | None = None):
    """Paged decode attention through block tables (interpret-mode harness;
    the serving path auto-dispatches via ``paged_attention.paged_attention``
    which falls back to the jnp reference off-TPU)."""
    return paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  scale=scale,
                                  interpret=_auto_interpret(interpret))
