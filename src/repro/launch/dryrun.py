import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] --out results.json

The first two lines above MUST precede any jax import: jax locks the device
count at first init, and only the dry-run wants 512 host devices.
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import SHAPES, ParallelConfig, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.launch.hlo_analysis import analyze_compiled
from repro.parallel import steps as S
from repro.parallel import planner
from repro.parallel import sharding
from repro.parallel.sharding import param_specs, opt_specs, to_shardings
from repro.core import costmodel


def default_pcfg(arch: str, kind: str,
                 multi_pod: bool = False) -> ParallelConfig:
    """Cost-model-chosen per-cell defaults: the old hand-written rule table
    is gone — ``planner.default_plan`` ranks the plan lattice with
    ``costmodel.train_memory_bytes`` / ``train_step_cost`` (see the ROADMAP
    plan-lattice table) and this returns the winner's config.  ``multi_pod``
    scores the (2,16,16) lattice (pod-extended fsdp becomes available)."""
    return planner.default_plan(arch, kind, multi_pod=multi_pod).to_pcfg()


def _cell_cfg(arch: str, kind: str):
    """Model config for a cell: serving runs bf16 params (inference norm)."""
    cfg = configs.get(arch)
    if kind != "train":
        cfg = cfg.replace(param_dtype="bfloat16")
    return cfg


def lower_cell(arch: str, shape_name: str, mesh, pcfg=None, cfg_override=None):
    shape = SHAPES[shape_name]
    cfg = cfg_override or _cell_cfg(arch, shape.kind)
    if hasattr(pcfg, "to_pcfg"):          # a first-class ParallelPlan
        pcfg = pcfg.to_pcfg()
    pcfg = pcfg or default_pcfg(arch, shape.kind,
                                multi_pod="pod" in mesh.axis_names)
    tcfg = TrainConfig()
    cell = build_cell(cfg, shape, mesh, pcfg)
    ctx = cell.ctx

    if shape.kind == "train":
        state = S.abstract_train_state(cfg, pcfg)
        state_sh = S.train_state_shardings(cfg, pcfg, ctx, state)
        fn = S.make_train_step(cfg, pcfg, tcfg, ctx)
        jitted = jax.jit(fn,
                         in_shardings=(state_sh,) + cell.in_shardings,
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, *cell.abstract_args)
    elif shape.kind == "prefill":
        params = jax.eval_shape(partial(_init_params, cfg=cfg))
        psh = to_shardings(param_specs(params, cfg, ctx), mesh)
        fn = S.make_prefill_step(cfg, pcfg, ctx)
        # donate the cache (args: params, batch, cache) — written in-pass
        jitted = jax.jit(fn, in_shardings=(psh,) + cell.in_shardings,
                         donate_argnums=(2,))
        lowered = jitted.lower(params, *cell.abstract_args)
    else:  # decode
        params = jax.eval_shape(partial(_init_params, cfg=cfg))
        psh = to_shardings(param_specs(params, cfg, ctx), mesh)
        fn = S.make_decode_step(cfg, pcfg, ctx)
        # donate the cache (args: params, token, cache, pos[, enc_out])
        jitted = jax.jit(fn, in_shardings=(psh,) + cell.in_shardings,
                         donate_argnums=(2,))
        lowered = jitted.lower(params, *cell.abstract_args)
    return lowered, cell


def _init_params(cfg):
    from repro.models import transformer as T
    from repro.models import encdec as E
    init = E.init if cfg.enc_dec else T.init
    return init(jax.random.PRNGKey(0), cfg)


def _inner_unrolled(cfg):
    """cfg with the chunk-scan unroll doubled (SSD/mLSTM inner loop probe)."""
    import dataclasses
    kw = {}
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, unroll=2)
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, unroll=2)
    return cfg.replace(**kw) if kw else None


def _probe(arch, shape_name, mesh, scan_unroll, inner: bool):
    """One lower+compile with probe unrolls; returns raw analysis."""
    shape = SHAPES[shape_name]
    cfg = _cell_cfg(arch, shape.kind)
    pcfg = default_pcfg(arch, shape.kind, multi_pod="pod" in mesh.axis_names)
    import dataclasses
    pcfg = dataclasses.replace(pcfg, scan_unroll=scan_unroll)
    cfg2 = _inner_unrolled(cfg) if inner else cfg
    pcfg, cfg2 = _apply_overrides(pcfg, cfg2)
    lowered, cell = lower_cell(arch, shape_name, mesh, pcfg=pcfg,
                               cfg_override=cfg2)
    compiled = lowered.compile()
    return analyze_compiled(compiled, mesh.size), cell, compiled


def _moe_ragged_overcount(cfg, shape, ctx, pcfg) -> float:
    """Per-device FLOPs that XLA's cost analysis over-counts for ragged_dot.

    CPU lowering (and cost analysis) treats ragged_dot as a DENSE dot over
    every expert group (verified: dense count for a (C,d)x(E,d,ff) ragged
    dot); on TPU Mosaic it executes ~C rows once.  We subtract the analytic
    overcount (E_groups−1)·2·C·d·ff per ragged_dot so the compute roofline
    term reflects the machine the mesh targets.  Recorded separately in the
    cell JSON (``flops_moe_overcount``)."""
    if cfg.moe is None or "attn_moe" not in cfg.block_pattern:
        return 0.0
    import math
    e = cfg.moe
    d, ff = cfg.d_model, e.d_ff_expert
    ep = ctx.model_size
    bs = 1
    for a in ctx.batch_axes:
        bs *= ctx.mesh.shape[a]
    t_loc = (shape.global_batch // bs) * (shape.seq_len if shape.kind != "decode" else 1)
    use_ep = e.n_experts % ep == 0 and e.n_experts >= ep
    if pcfg.moe_a2a_ep and "data" in ctx.batch_axes:
        dp = ctx.mesh.shape["data"]
        e_groups = e.n_experts // dp
        cap = dp * max(8, int(math.ceil(t_loc * e.top_k / dp * e.capacity_factor)))
        over_per_rd = 2.0 * cap * d * (ff / ep) * (e_groups - 1)
    elif use_ep:
        e_groups = e.n_experts // ep
        cap = max(8, min(int(math.ceil(t_loc * e.top_k / ep * e.capacity_factor)),
                         t_loc * e.top_k))
        over_per_rd = 2.0 * cap * d * ff * (e_groups - 1)
    else:
        e_groups = e.n_experts
        cap = t_loc * e.top_k
        over_per_rd = 2.0 * cap * d * (ff / ep) * (e_groups - 1)
    passes = 4.0 if shape.kind == "train" else 1.0  # fwd+bwd(2)+remat
    n_moe = cfg.block_pattern.count("attn_moe") * cfg.n_periods
    return over_per_rd * 3 * passes * n_moe


HILLCLIMB_OVERRIDES = {"pcfg": {}, "cfg": {}}  # set by --hc-* CLI flags


def _apply_overrides(pcfg, cfg):
    import dataclasses
    if HILLCLIMB_OVERRIDES["pcfg"]:
        pcfg = dataclasses.replace(pcfg, **HILLCLIMB_OVERRIDES["pcfg"])
    for k, v in HILLCLIMB_OVERRIDES["cfg"].items():
        if k == "mm_bf16":
            if cfg.ssm is not None:
                cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, mm_bf16=v))
            if cfg.xlstm is not None:
                cfg = cfg.replace(xlstm=dataclasses.replace(cfg.xlstm, mm_bf16=v))
        else:
            cfg = cfg.replace(**{k: v})
    return pcfg, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             no_probes: bool = False):
    """Lower+compile with XLA's scan-body-counted-once quirk corrected:
    cost_analysis counts a while-loop body once regardless of trip count, so
    we probe with layer-scan unroll 1 and 2 (and inner chunk-scan unroll for
    SSD/mLSTM archs) and solve  measured(u_o, u_i) = A + u_o·B + u_o·u_i·C
    for the true  A + P·B + P·C_i·C  (P = layer-scan trips, C_i = chunk-scan
    trips).  sLSTM's per-token scan is left uncorrected (elementwise,
    negligible flops; noted in EXPERIMENTS.md)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n = mesh.size
    shape = SHAPES[shape_name]
    cfg = _cell_cfg(arch, shape.kind)

    sharding.reset_dropped_partitions()
    t0 = time.time()
    rec, cell, compiled = _probe(arch, shape_name, mesh, 1, False)
    t1 = time.time()
    if no_probes:
        p21 = rec
    else:
        p21, _, _ = _probe(arch, shape_name, mesh, 2, False)

    trips = cfg.n_layers if cfg.enc_dec else cfg.n_periods
    has_chunks = (cfg.ssm is not None or cfg.xlstm is not None) \
        and shape.kind != "decode"
    chunk = (cfg.ssm.chunk if cfg.ssm else cfg.xlstm.chunk) if has_chunks else 1
    inner_trips = max(1, shape.seq_len // chunk) if has_chunks else 1

    if has_chunks and inner_trips > 1 and not no_probes:
        p22, _, _ = _probe(arch, shape_name, mesh, 2, True)
    else:
        p22 = None

    def corrected(metric):
        m11 = metric(rec)
        m21 = metric(p21)
        if p22 is not None:
            m22 = metric(p22)
            c = m22 - m21
            b = (m21 - m11) - c
            a = m11 - b - c
            out = a + trips * b + trips * inner_trips * c
        else:
            b = m21 - m11
            a = m11 - b
            out = a + trips * b
        # physical floor: the true total can't be below the once-counted
        # measurement (probe noise from fusion differences can go negative)
        return max(out, m11)

    flops_dev = corrected(lambda r: r["flops_per_device"])
    pcfg_eff, _ = _apply_overrides(
        default_pcfg(arch, shape.kind, multi_pod=multi_pod), cfg)
    over_dev = _moe_ragged_overcount(cfg, shape, cell.ctx, pcfg_eff)
    flops_dev = max(flops_dev - over_dev, 0.0)
    bytes_dev = corrected(lambda r: r["bytes_per_device"])
    wire_dev = corrected(lambda r: r["collectives"]["wire_bytes"])
    coll_per_op = {
        k: {kk: corrected(lambda r, k=k, kk=kk: r["collectives"]["per_op"][k][kk])
            for kk in ("result_bytes", "wire_bytes")}
        | {"count_in_text": rec["collectives"]["per_op"][k]["count"]}
        for k in rec["collectives"]["per_op"]
    }
    t2 = time.time()

    pc = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        model_flops = costmodel.model_flops_train(pc["active"], tokens)
    else:
        model_flops = 2.0 * pc["active"] * tokens
    terms = costmodel.roofline_terms(flops_dev * n, bytes_dev * n, wire_dev * n, n)
    rec.update({
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "flops_per_device": flops_dev,
        "hlo_flops_global": flops_dev * n,
        "bytes_per_device": bytes_dev,
        "collectives_corrected": {"wire_bytes": wire_dev, "per_op": coll_per_op},
        "scan_trips": trips, "chunk_trips": inner_trips,
        "flops_moe_overcount_per_device": over_dev,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(flops_dev * n, 1.0),
        "roofline": terms,
        "compile_s": t1 - t0, "probe_s": t2 - t1,
        "batch_axes": list(cell.ctx.batch_axes),
        # partitions the rule table asked for but the shapes didn't divide —
        # the layout the planner scored vs the one that actually ran
        "sharding_dropped": sharding.dropped_partition_report(),
    })
    if verbose:
        mem = rec["memory"]
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"compile {rec['compile_s']:.1f}s+{rec['probe_s']:.1f}s  "
              f"mem/dev args={mem['argument_bytes']/2**30:.2f}GiB "
              f"temp={mem['temp_bytes']/2**30:.2f}GiB  "
              f"flops/dev={rec['flops_per_device']:.3e}  "
              f"useful={rec['useful_flops_ratio']:.2f}  "
              f"dominant={terms['dominant']} ({terms['bound_s']*1e3:.2f} ms)"
              + (f"  dropped_shards={len(rec['sharding_dropped'])}"
                 if rec["sharding_dropped"] else ""))
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis(corrected): flops/dev=%.4e bytes/dev=%.4e wire/dev=%.4e" %
              (rec["flops_per_device"], rec["bytes_per_device"],
               rec["collectives_corrected"]["wire_bytes"]))
        print("  collectives:", json.dumps(rec["collectives_corrected"]["per_op"]))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    # §Perf hillclimb knobs
    ap.add_argument("--hc-seq-parallel", action="store_true")
    ap.add_argument("--hc-a2a-ep", action="store_true")
    ap.add_argument("--hc-engine-replicate", action="store_true")
    ap.add_argument("--hc-mm-bf16", action="store_true")
    ap.add_argument("--hc-remat", default=None, choices=["none", "dots", "full"])
    ap.add_argument("--hc-logit-chunk", type=int, default=None)
    ap.add_argument("--hc-no-fsdp", action="store_true")
    ap.add_argument("--hc-master-bf16", action="store_true")
    ap.add_argument("--hc-grad-barrier", action="store_true")
    ap.add_argument("--hc-manual-attention", action="store_true")
    ap.add_argument("--hc-dp-over-model", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="single compile per cell (multi-pod shard-proof "
                         "pass; roofline numbers uncorrected)")
    args = ap.parse_args()
    if args.hc_seq_parallel:
        HILLCLIMB_OVERRIDES["pcfg"]["sequence_parallel"] = True
    if args.hc_a2a_ep:
        HILLCLIMB_OVERRIDES["pcfg"]["moe_a2a_ep"] = True
    if args.hc_engine_replicate:
        HILLCLIMB_OVERRIDES["pcfg"]["engine_replicate"] = True
    if args.hc_remat:
        HILLCLIMB_OVERRIDES["pcfg"]["remat"] = args.hc_remat
    if args.hc_logit_chunk:
        HILLCLIMB_OVERRIDES["pcfg"]["logit_chunk"] = args.hc_logit_chunk
    if args.hc_no_fsdp:
        HILLCLIMB_OVERRIDES["pcfg"]["fsdp_params"] = False
        HILLCLIMB_OVERRIDES["pcfg"]["fsdp_pod"] = False
    if args.hc_mm_bf16:
        HILLCLIMB_OVERRIDES["cfg"]["mm_bf16"] = True
    if args.hc_master_bf16:
        HILLCLIMB_OVERRIDES["pcfg"]["master_weights"] = True
    if args.hc_grad_barrier:
        HILLCLIMB_OVERRIDES["pcfg"]["grad_barrier"] = True
    if args.hc_manual_attention:
        HILLCLIMB_OVERRIDES["pcfg"]["manual_attention"] = True
    if args.hc_dp_over_model:
        HILLCLIMB_OVERRIDES["pcfg"]["dp_over_model"] = True

    results = []
    if args.all:
        todo = [(a, s, sk) for (a, s, sk) in configs.cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, False)]

    failures = []
    for arch, shape_name, skip in todo:
        if skip:
            results.append({"arch": arch, "shape": shape_name, "skipped": True,
                            "reason": "full-attention arch; long_500k requires "
                                      "sub-quadratic attention (DESIGN.md §4)"})
            print(f"[{arch} × {shape_name}] SKIP (full attention)")
            continue
        try:
            results.append(run_cell(arch, shape_name, args.multi_pod,
                                    no_probes=args.no_probes))
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, str(e)))
            results.append({"arch": arch, "shape": shape_name, "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:", *[f"{a}×{s}: {e[:200]}" for a, s, e in failures],
              sep="\n")
        sys.exit(1)
    print(f"\nall {len(results)} cells OK")


if __name__ == "__main__":
    main()
