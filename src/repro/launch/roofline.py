"""Roofline report generator: reads dry-run JSONs and emits the
EXPERIMENTS.md §Roofline tables (per-cell three-term roofline, dominant
bottleneck, MODEL_FLOPS ratio, and a one-line recommendation).

  PYTHONPATH=src python -m repro.launch.roofline results/dryrun_16x16.json

Also hosts the parallel-matmul scenario table (paper §4 + the 2D family):

  PYTHONPATH=src python -m repro.launch.roofline --matmul n=8192,p=64

the serving-path table (continuous-batching scheduler vs naive, from
``costmodel.decode_step_cost`` / ``prefill_cost``):

  PYTHONPATH=src python -m repro.launch.roofline --serve arch=llama3.2-3b,prompt=2048,gen=256,chips=16

and the auto-parallel plan-lattice table (``parallel/planner.py`` ranked by
the Table-1 train-step model, with measured zero-vs-allreduce numbers from
``BENCH_train.json`` when present):

  PYTHONPATH=src python -m repro.launch.roofline --plan arch=llama3.2-3b,batch=256,seq=4096,mesh=16x16
"""
from __future__ import annotations

import json
import math
import os
import sys

from repro.core import costmodel


def recommend(rec: dict) -> str:
    """One sentence: what moves the dominant term down."""
    dom = rec["roofline"]["dominant"]
    kind = rec["kind"]
    per_op = rec.get("collectives_corrected", {}).get("per_op", {})
    if dom == "collective_s":
        big = max(per_op, key=lambda k: per_op[k]["wire_bytes"]) if per_op else "?"
        return (f"dominant collective is {big}: cast the f32 backward "
                "segments to bf16 and replace grad all-reduce with "
                "reduce-scatter (ZeRO), then overlap with compute")
    if dom == "memory_s":
        if kind == "decode":
            return ("decode is KV-cache-bandwidth bound (expected): raise "
                    "batch or quantize the cache to int8")
        return ("bytes/FLOP too high: fuse attention (Pallas flash kernel "
                "keeps scores in VMEM) and drop the remat policy to 'dots'")
    return ("compute-bound — at the roofline; remaining headroom is only "
            "remat overhead (useful-FLOPs ratio "
            f"{rec.get('useful_flops_ratio', 0):.2f})")


def fraction_of_roofline(rec: dict) -> float:
    """Useful-compute time / bound time: MODEL_FLOPS/(chips·peak) vs the
    dominant term — the score §Perf optimizes."""
    t_useful = rec["model_flops"] / (rec["chips"] * costmodel.PEAK_FLOPS_BF16)
    return t_useful / max(rec["roofline"]["bound_s"], 1e-12)


def table(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful/HLO | roofline-frac | fix |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"(full attention @500k) | — | — | — |")
            continue
        if r.get("error"):
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:60]} |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {fraction_of_roofline(r):.3f} | "
            f"{recommend(r)[:80]} |")
    return "\n".join(out)


def matmul_scenarios_table(n: int, p: int, bytes_per_elt: int = 2) -> str:
    """Predicted time / efficiency / memory of every parallel-matmul variant
    in the repo on p chips, from the Table-1 cost model.  DNS needs a cube
    grid, SUMMA/Cannon a square one; rows are skipped when p doesn't fit."""
    rows = ["| algorithm | grid | total_s | efficiency | per-proc elts | "
            "isoefficiency W(p) |", "|---|---|---|---|---|---|"]

    def eff(c):
        return c["serial_s"] / (c["p"] * c["total_s"])

    q3 = round(p ** (1 / 3))
    if q3**3 == p and n % q3 == 0:
        c = costmodel.dns_matmul_cost(n, q3, bytes_per_elt)
        rows.append(f"| DNS (3D) | {q3}³ | {c['total_s']:.4g} | {eff(c):.3f} | "
                    f"{3 * (n // q3) ** 2} (×{q3} replicated) | "
                    f"{costmodel.isoefficiency_matmul_grid(p):.3g} |")
    q2 = round(math.isqrt(p))
    if q2 * q2 == p and n % q2 == 0:
        c = costmodel.summa_matmul_cost(n, q2, bytes_per_elt=bytes_per_elt)
        rows.append(f"| SUMMA (2D) | {q2}² | {c['total_s']:.4g} | {eff(c):.3f} | "
                    f"{c['mem_elts_per_proc']} | "
                    f"{costmodel.isoefficiency_matmul_summa(p):.3g} |")
        c = costmodel.summa_pipelined_cost(n, q2, bytes_per_elt=bytes_per_elt)
        rows.append(f"| SUMMA-pipelined (2D, overlap) | {q2}² | "
                    f"{c['total_s']:.4g} | {eff(c):.3f} | "
                    f"{c['mem_elts_per_proc']} | "
                    f"{costmodel.isoefficiency_matmul_cannon(p):.3g} |")
        c = costmodel.cannon_matmul_cost(n, q2, bytes_per_elt=bytes_per_elt)
        rows.append(f"| Cannon (2D) | {q2}² | {c['total_s']:.4g} | {eff(c):.3f} | "
                    f"{c['mem_elts_per_proc']} | "
                    f"{costmodel.isoefficiency_matmul_cannon(p):.3g} |")
    # 2.5D: the largest replication factor c with p = q²c, c | q fixes (q, c)
    for c25 in sorted({d for d in range(2, p + 1) if p % d == 0}, reverse=True):
        q25 = round(math.isqrt(p // c25))
        if q25 * q25 * c25 == p and c25 <= q25 and q25 % c25 == 0 \
                and n % q25 == 0:
            c = costmodel.cannon_25d_cost(n, q25, c25, bytes_per_elt=bytes_per_elt)
            rows.append(f"| Cannon-2.5D (×{c25} replicated) | {q25}²×{c25} | "
                        f"{c['total_s']:.4g} | {eff(c):.3f} | "
                        f"{c['mem_elts_per_proc']} | "
                        f"{costmodel.isoefficiency_matmul_25d(p, c25):.3g} |")
            break
    rows.append(f"| generic (1D, Alg. 1) | {p} | — | — | — | "
                f"{costmodel.isoefficiency_matmul_generic(p):.3g} |")
    return "\n".join(rows)


def kv_bytes_per_seq(cfg, seq: int) -> float:
    """Per-sequence decode-cache traffic: attention KV (bf16, window-capped)
    plus the recurrent-state leaves (conv window + f32 SSM/mLSTM state)."""
    kv_len = min(seq, cfg.window) if cfg.window else seq
    kv_line = 2 * kv_len * cfg.n_kv_heads * cfg.hd * 2          # k+v, bf16
    if cfg.enc_dec:
        return cfg.n_layers * kv_line
    total = 0.0
    for kind in cfg.block_pattern:
        if kind in ("attn", "attn_moe"):
            total += kv_line
        elif kind in ("mamba2", "mamba2_attn"):
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            total += (s.conv_width - 1) * (d_in + 2 * s.d_state) * 2
            total += (d_in // s.head_dim) * s.d_state * s.head_dim * 4
            if kind == "mamba2_attn":
                total += kv_line
        elif kind == "mlstm":
            d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
            hd = d_in // cfg.n_heads
            total += cfg.n_heads * hd * (hd + 1) * 4
        elif kind == "slstm":
            total += 3 * cfg.d_model * 4
    return total * cfg.n_periods


def serve_table(arch: str, prompt: int, gen: int, chips: int = 1) -> str:
    """Predicted serving throughput/latency of the continuous-batching
    scheduler at growing slot counts vs the naive one-slot server: decode is
    batch-amortized memory-bound (params stream once per step regardless of
    batch), so tok/s climbs near-linearly until KV traffic or the MXU takes
    over — the model the BENCH_serve.json A/B is checked against."""
    from repro import configs
    cfg = configs.get(arch)
    n_active = cfg.param_counts()["active"]
    kv = kv_bytes_per_seq(cfg, prompt + gen)
    pre = costmodel.prefill_cost(n_active, prompt, chips=chips)
    naive = costmodel.decode_step_cost(n_active, 1, kv, chips=chips)
    rows = [f"| slots | step_compute_s | step_memory_s | dominant | tok/s | "
            f"request latency_s | speedup vs 1 |", "|---|---|---|---|---|---|---|"]
    for b in (1, 8, 32, 128, 512):
        c = costmodel.decode_step_cost(n_active, b, kv, chips=chips)
        lat = pre["total_s"] + gen * c["total_s"]
        rows.append(
            f"| {b} | {c['compute_s']:.3e} | {c['memory_s']:.3e} | "
            f"{c['dominant'].replace('_s', '')} | {c['tok_s']:.1f} | "
            f"{lat:.3f} | {c['tok_s'] / naive['tok_s']:.1f}× |")
    rows.append(f"(prefill {prompt} toks: {pre['total_s'] * 1e3:.2f} ms fused "
                f"vs {prompt * naive['total_s'] * 1e3:.2f} ms as a decode "
                f"loop — {cfg.name}, {chips} chip(s))")
    # paged engine: page-table-gather tax vs block size, and the chunked-
    # prefill stall bound vs the fused call's whole-prompt stall
    kv_tok = kv_bytes_per_seq(cfg, 1)
    rows.append("")
    rows.append("| paged (32 slots) | block | pages/seq | tok/s | vs dense | "
                "chunk | admission stall_s |")
    rows.append("|---|---|---|---|---|---|---|")
    dense = costmodel.decode_step_cost(n_active, 32, kv, chips=chips)
    for blk, chunk in ((16, 256), (64, 1024), (256, 4096)):
        pc = costmodel.paged_decode_step_cost(n_active, 32, kv, block=blk,
                                              kv_token_bytes=kv_tok,
                                              chips=chips)
        cp = costmodel.chunked_prefill_cost(n_active, prompt, chunk,
                                            chips=chips,
                                            kv_token_bytes=kv_tok)
        rows.append(f"| paged | {blk} | {pc['pages_per_seq']} | "
                    f"{pc['tok_s']:.1f} | {pc['tok_s'] / dense['tok_s']:.3f}× "
                    f"| {chunk} | {cp['stall_s']:.3e} |")
    rows.append(f"(fused prefill stalls every in-flight decode for "
                f"{pre['total_s']:.3e} s; a chunk stalls it for one slice — "
                f"the paged/chunked engine caps it at the chunk column)")
    return "\n".join(rows)


def plan_table(arch: str, batch: int, seq: int, mesh: tuple,
               kind: str = "train") -> str:
    """Ranked plan lattice for one (arch × shape) cell, plus the measured
    zero-vs-allreduce A/B from ``BENCH_train.json`` (written by
    ``benchmarks/run.py --only train``) as predicted-vs-measured ground
    truth for the two grad strategies."""
    from repro import configs
    from repro.parallel import planner
    cfg = configs.get(arch)
    ranked = planner.plan_search(cfg, mesh, batch, seq, kind)
    out = [f"### plan lattice — {arch} × {kind} b={batch} s={seq} "
           f"mesh={'x'.join(map(str, mesh))}", "",
           planner.format_plan_table(ranked)]
    bench = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "BENCH_train.json")
    if os.path.exists(bench):
        rows = json.load(open(bench))
        out.append("")
        out.append("measured A/B (BENCH_train.json, reduced config on the "
                   "CPU mesh; model_us from the same train_step_cost that "
                   "ranked the lattice):")
        for name, r in sorted(rows.items()):
            out.append(f"  {name}: measured {r['us_per_call']} us/step vs "
                       f"predicted {r['model_us']} us "
                       f"(scatter group {r.get('shards', '?')})")
    return "\n".join(out)


def main():
    args = sys.argv[1:]
    if args and args[0] == "--plan":
        try:
            kv = dict(s.split("=") for s in args[1].split(",")) if len(args) > 1 else {}
            arch = kv.get("arch", "llama3.2-3b")
            batch, seq = int(kv.get("batch", 256)), int(kv.get("seq", 4096))
            mesh = tuple(int(d) for d in kv.get("mesh", "16x16").split("x"))
            kind = kv.get("kind", "train")
        except ValueError:
            raise SystemExit(
                "usage: roofline --plan arch=<name>,batch=<n>,seq=<n>,"
                "mesh=<d>x<d>[,kind=train|decode]")
        print(plan_table(arch, batch, seq, mesh, kind))
        return
    if args and args[0] == "--serve":
        try:
            kv = dict(s.split("=") for s in args[1].split(",")) if len(args) > 1 else {}
            arch = kv.get("arch", "llama3.2-3b")
            prompt, gen = int(kv.get("prompt", 2048)), int(kv.get("gen", 256))
            chips = int(kv.get("chips", 1))
        except ValueError:
            raise SystemExit(
                "usage: roofline --serve arch=<name>,prompt=<len>,gen=<len>,chips=<n>")
        print(serve_table(arch, prompt, gen, chips))
        return
    if args and args[0] == "--matmul":
        try:
            kv = dict(s.split("=") for s in args[1].split(",")) if len(args) > 1 else {}
            n, p = int(kv.get("n", 8192)), int(kv.get("p", 64))
        except ValueError:
            raise SystemExit("usage: roofline --matmul n=<size>,p=<chips>")
        print(matmul_scenarios_table(n, p))
        return
    for path in args:
        print(f"\n### {path}\n")
        print(table(path))


if __name__ == "__main__":
    main()
