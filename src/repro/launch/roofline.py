"""Roofline report generator: reads dry-run JSONs and emits the
EXPERIMENTS.md §Roofline tables (per-cell three-term roofline, dominant
bottleneck, MODEL_FLOPS ratio, and a one-line recommendation).

  PYTHONPATH=src python -m repro.launch.roofline results/dryrun_16x16.json
"""
from __future__ import annotations

import json
import sys

from repro.core import costmodel


def recommend(rec: dict) -> str:
    """One sentence: what moves the dominant term down."""
    dom = rec["roofline"]["dominant"]
    kind = rec["kind"]
    per_op = rec.get("collectives_corrected", {}).get("per_op", {})
    if dom == "collective_s":
        big = max(per_op, key=lambda k: per_op[k]["wire_bytes"]) if per_op else "?"
        return (f"dominant collective is {big}: cast the f32 backward "
                "segments to bf16 and replace grad all-reduce with "
                "reduce-scatter (ZeRO), then overlap with compute")
    if dom == "memory_s":
        if kind == "decode":
            return ("decode is KV-cache-bandwidth bound (expected): raise "
                    "batch or quantize the cache to int8")
        return ("bytes/FLOP too high: fuse attention (Pallas flash kernel "
                "keeps scores in VMEM) and drop the remat policy to 'dots'")
    return ("compute-bound — at the roofline; remaining headroom is only "
            "remat overhead (useful-FLOPs ratio "
            f"{rec.get('useful_flops_ratio', 0):.2f})")


def fraction_of_roofline(rec: dict) -> float:
    """Useful-compute time / bound time: MODEL_FLOPS/(chips·peak) vs the
    dominant term — the score §Perf optimizes."""
    t_useful = rec["model_flops"] / (rec["chips"] * costmodel.PEAK_FLOPS_BF16)
    return t_useful / max(rec["roofline"]["bound_s"], 1e-12)


def table(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful/HLO | roofline-frac | fix |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"(full attention @500k) | — | — | — |")
            continue
        if r.get("error"):
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:60]} |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {fraction_of_roofline(r):.3f} | "
            f"{recommend(r)[:80]} |")
    return "\n".join(out)


def main():
    for path in sys.argv[1:]:
        print(f"\n### {path}\n")
        print(table(path))


if __name__ == "__main__":
    main()
