"""Fill EXPERIMENTS.md placeholders from the dry-run / hillclimb JSONs.

  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import glob
import json
import os
import re

from repro.launch.roofline import table, fraction_of_roofline

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
RES = os.path.join(ROOT, "results")


def hc_rows():
    """Hillclimb result lines, compared against baseline cells."""
    base = {}
    with open(os.path.join(RES, "dryrun_16x16.json")) as f:
        for r in json.load(f):
            if "roofline" in r:
                base[(r["arch"], r["shape"])] = r
    lines = []
    for path in sorted(glob.glob(os.path.join(RES, "hc_*.json"))):
        name = os.path.basename(path)[3:-5]
        rows = json.load(open(path))
        if not rows or "roofline" not in rows[0]:
            lines.append(f"| {name} | FAILED | | | | |")
            continue
        r = rows[0]
        b = base.get((r["arch"], r["shape"]))
        t, bt = r["roofline"], b["roofline"]
        lines.append(
            f"| {name} | {r['arch']}×{r['shape']} | "
            f"{bt['bound_s']:.3f}→{t['bound_s']:.3f} "
            f"({bt['bound_s']/max(t['bound_s'],1e-12):.1f}×) | "
            f"{bt['dominant'].replace('_s','')}→{t['dominant'].replace('_s','')} | "
            f"{fraction_of_roofline(b):.4f}→{fraction_of_roofline(r):.4f} | "
            f"c={t['compute_s']:.2f} m={t['memory_s']:.2f} "
            f"x={t['collective_s']:.2f} |")
    return "\n".join(lines)


def _fill(text, name, body):
    """Idempotent region fill between <!-- name --> and <!-- /name -->."""
    return re.sub(rf"<!-- {name} -->.*?<!-- /{name} -->",
                  f"<!-- {name} -->\n{body}\n<!-- /{name} -->", text, flags=re.S)


def main():
    text = open(EXP).read()
    t1 = table(os.path.join(RES, "dryrun_16x16.json"))
    text = _fill(text, "ROOFLINE_16x16",
                 f"\n### 16×16 (single pod, corrected)\n\n{t1}\n")
    p2 = os.path.join(RES, "dryrun_2x16x16.json")
    if os.path.exists(p2):
        t2 = table(p2)
        text = _fill(text, "ROOFLINE_2x16x16",
                     "\n### 2×16×16 (multi-pod shard-proof pass; single "
                     "compile, uncorrected scan trip counts — see §Dry-run "
                     f"methodology)\n\n{t2}\n")
    if glob.glob(os.path.join(RES, "hc_*.json")):
        hdr = ("| run | cell | bound_s before→after | dominant | "
               "roofline-frac | terms after |\n|---|---|---|---|---|---|")
        text = _fill(text, "PERF_LOG", f"\n{hdr}\n{hc_rows()}\n")
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
