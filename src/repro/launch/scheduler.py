"""Slot-based continuous-batching decode scheduler (the serving subsystem).

Design (ROADMAP "real-traffic serving path"):

  * A fixed pool of ``slots`` cache rows backs one fixed-shape jitted decode
    step: the per-slot position vector lets every request advance
    independently, so new requests join and finished ones leave mid-flight
    without retracing.
  * Eviction: after ``gen`` tokens the slot returns to the free list; a
    parked slot keeps riding the batched step (fixed shapes) but its writes
    stay causally invisible to the next occupant (end-aligned: hidden
    behind the causal mask; paged: dropped through its freed block table).
  * Arrivals are measured in engine ticks (decode steps), giving a
    deterministic, machine-independent arrival process; wall-clock is used
    only for the reported latency/throughput metrics.

Cache layout / admission scenarios (``paged=`` selects the engine; one
fixed-shape jitted decode step serves both):

  | scenario           | cache layout            | admission (prefill)      | request length limit        |
  |--------------------|-------------------------|--------------------------|-----------------------------|
  | end-aligned (dflt) | per-slot (max_len) row  | ONE fused cache-writing  | prompt+gen <= max_len per   |
  |                    |                         | forward, bucketed padded | slot (<= window for SWA)    |
  | paged              | shared page arena +     | CHUNKED: fixed (1,chunk) | prompt+gen <= pool capacity |
  |                    | per-request block table | slices interleaved with  | (and the block-table width  |
  |                    | (serving/kvcache.py)    | decode ticks             | cap max_len)                |
  | recurrent fallback | state leaves (no        | per-token B=1 loop (pad  | prompt+gen <= max_len       |
  | (mamba2/m/sLSTM)   | position indexing)      | would corrupt the state) |                             |

End-aligned admission stalls every in-flight decode for a whole prompt
forward; chunked prefill bounds that stall to one ``chunk``-token slice per
tick (``costmodel.chunked_prefill_cost`` models the tradeoff) and makes
prompts of any length schedulable.  The paged engine addresses K/V through
per-request page chains (``serving.BlockPool``), so ``prompt + gen`` is
bounded by *pool capacity* rather than any per-slot rectangle — requests an
end-aligned slot must reject outright are servable
(``benchmarks/_serve_throughput.py`` measures the A/B).

The naive one-request-at-a-time server is this same engine with ``slots=1``.
Cost-model predictions come from ``costmodel.decode_step_cost`` /
``paged_decode_step_cost`` / ``prefill_cost`` / ``chunked_prefill_cost``
(``roofline --serve``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import ModelConfig, ParallelConfig
from repro.models import transformer as T
from repro.parallel import steps as S
from repro.serving import BlockPool


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float,
                  top_p: float = 1.0) -> jax.Array:
    """Temperature / top-p (nucleus) sampling over ``(B, V)`` logits;
    ``temperature == 0`` is greedy argmax (the scheduler's default and the
    test oracle).  Top-p keeps the smallest prefix of the sorted
    distribution whose mass exceeds ``top_p`` (the top token always
    survives), masks the rest to -inf, and samples the renormalized tail."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # temperature first, nucleus second (the conventional order): the top-p
    # mass is measured on the tempered distribution, so raising T widens
    # the kept set
    logits = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < top_p            # mass before this token < p
        last = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)
        thresh = jnp.take_along_axis(sorted_l, last[..., None], axis=-1)
        logits = jnp.where(logits >= thresh, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: Sequence[int]          # token ids; may be empty (generate from BOS)
    gen: int                       # tokens to generate, >= 1
    arrival: int = 0               # engine tick at which the request appears


@dataclass
class Completion:
    rid: int
    tokens: List[int]
    arrival: int
    admitted_tick: int
    done_tick: int
    admitted_s: float              # wall seconds from run start
    first_token_s: float           # wall seconds from run start
    done_s: float

    @property
    def ttft_s(self) -> float:
        """Admission → first token (prefill latency; queue wait is virtual
        ticks, so pre-admission wall time is not a serving latency)."""
        return self.first_token_s - self.admitted_s


@dataclass
class _Slot:
    req: Request
    tokens: List[int] = field(default_factory=list)
    admitted_tick: int = 0
    admitted_s: float = 0.0
    first_token_s: float = 0.0
    state: str = "decode"          # "prefill" while chunked prefill runs
    cursor: int = 0                # prompt tokens consumed (paged prefill)


class Scheduler:
    """Continuous-batching decode engine over a fixed slot pool (end-aligned
    cache rows, or the paged block-pool arena with ``paged=True``)."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, params, *,
                 slots: int = 4, max_len: int = 256, bucket: int = 16,
                 bos: int = 0, ctx=None, temperature: float = 0.0,
                 top_p: float = 1.0, seed: int = 0, paged: bool = False,
                 block: int = 16, pool_blocks: Optional[int] = None,
                 chunk: int = 32):
        if cfg.enc_dec:
            raise NotImplementedError("enc-dec serving is not scheduled yet")
        if slots < 1 or max_len < 2:
            raise ValueError(f"need slots >= 1 and max_len >= 2, got "
                             f"{slots}/{max_len}")
        if temperature < 0.0 or not 0.0 < top_p <= 1.0:
            raise ValueError(f"need temperature >= 0 and 0 < top_p <= 1, "
                             f"got {temperature}/{top_p}")
        if not paged and cfg.window is not None and max_len > cfg.window:
            raise NotImplementedError(
                f"slots are end-aligned: max_len {max_len} must fit the "
                f"attention window {cfg.window}")
        if hasattr(pcfg, "to_pcfg"):          # a first-class ParallelPlan
            pcfg = pcfg.to_pcfg()
        self.cfg, self.pcfg, self.params, self.ctx = cfg, pcfg, params, ctx
        self.slots, self.max_len = slots, max_len
        self.bucket, self.bos = max(1, bucket), bos
        self.temperature, self.top_p, self.seed = temperature, top_p, seed
        self.sampling = temperature > 0.0
        self.paged = paged
        self.fused = T.supports_fused_prefill(cfg)
        if paged:
            if not T.supports_paged(cfg):
                raise NotImplementedError(
                    f"paged serving needs a pure-attention no-SWA pattern; "
                    f"got {cfg.block_pattern} (window={cfg.window})")
            if block < 1 or chunk < 1:
                raise ValueError(f"need block >= 1 and chunk >= 1, got "
                                 f"{block}/{chunk}")
            self.block, self.chunk = block, chunk
            self.n_pages = -(-max_len // block)      # block-table width
            self.pool = BlockPool(
                pool_blocks if pool_blocks is not None
                else slots * self.n_pages, block)
        if self.sampling:
            # logits-returning decode + per-tick sampling, one fused jit:
            # every slot samples from its own row (parked rows ride along)
            base = S.make_decode_step(cfg, pcfg, ctx, return_logits=True,
                                      paged=paged)
            if paged:
                def _sampled(p, tok, cache, pos, tables, key):
                    logits, new_cache = base(p, tok, cache, pos, tables)
                    return (sample_tokens(logits, key, temperature, top_p),
                            new_cache)
            else:
                def _sampled(p, tok, cache, pos, key):
                    logits, new_cache = base(p, tok, cache, pos)
                    return (sample_tokens(logits, key, temperature, top_p),
                            new_cache)

            self._decode = jax.jit(_sampled, donate_argnums=(2,))
        else:
            self._decode = jax.jit(S.make_decode_step(cfg, pcfg, ctx,
                                                      paged=paged),
                                   donate_argnums=(2,))
        if paged:
            self._chunk_prefill = jax.jit(
                S.make_chunk_prefill_step(cfg, pcfg, ctx), donate_argnums=(2,))
            self._prefill = self._prefill_logits = self._decode_greedy = None
        else:
            # unpadded per-token prefill fallback is always greedy-shaped
            # (its intermediate outputs are ignored; the last token is
            # re-sampled)
            self._decode_greedy = self._decode if not self.sampling else \
                jax.jit(S.make_decode_step(cfg, pcfg, ctx), donate_argnums=(2,))
            self._prefill = jax.jit(S.make_prefill_step(cfg, pcfg, ctx),
                                    donate_argnums=(2,)) if self.fused else None
            self._prefill_logits = jax.jit(
                S.make_decode_step(cfg, pcfg, ctx, return_logits=True),
                donate_argnums=(2,)) if self.sampling and not self.fused else None
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.reset()

    def reset(self) -> None:
        """Fresh cache/pool + slot state and an empty submission queue (jit
        caches survive — use for warmup); the sampling stream restarts from
        the seed for reproducible runs."""
        if self.paged:
            self.cache = T.init_paged_cache(self.cfg, self.pool.n_blocks,
                                            self.block)
            self.pool.reset()
            self._tables = np.full((self.slots, self.n_pages), -1, np.int32)
        else:
            self.cache = T.init_cache(self.cfg, self.slots, self.max_len)
        self._tok = np.zeros((self.slots,), np.int32)
        self._pos = np.zeros((self.slots,), np.int32)
        self._key = jax.random.PRNGKey(self.seed)
        self._queue: List[Request] = []

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    @staticmethod
    def _insert_impl(big, small, slot):
        return jax.tree.map(
            lambda bg, sm: lax.dynamic_update_slice(
                bg, sm.astype(bg.dtype), (0, slot) + (0,) * (bg.ndim - 2)),
            big, small)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Validate and enqueue one request (``run`` drains the queue).
        Length limits are enforced HERE, with the limit named, instead of
        failing deep inside admission: end-aligned mode is bounded by the
        per-slot row, paged mode by pool capacity and the block-table
        width."""
        lp = len(req.prompt)
        total = lp + req.gen
        if req.gen < 1 or req.arrival < 0:
            raise ValueError(f"request {req.rid}: need gen >= 1 and "
                             f"arrival >= 0, got {req.gen}/{req.arrival}")
        if self.paged:
            if total > self.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt {lp} + gen {req.gen} = "
                    f"{total} tokens exceeds the block-table width cap "
                    f"max_len={self.max_len} ({self.n_pages} pages x block "
                    f"{self.block})")
            need = self.pool.blocks_needed(total)
            if need > self.pool.n_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {lp} + gen {req.gen} = "
                    f"{total} tokens needs {need} pages, pool capacity is "
                    f"{self.pool.n_blocks} blocks x {self.block} tokens")
        elif total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {lp} + gen {req.gen} = {total} "
                f"tokens exceeds the end-aligned slot capacity "
                f"max_len={self.max_len}")
        self._queue.append(req)

    # ------------------------------------------------------------------
    def _bucketed(self, n: int) -> int:
        return min(self.max_len, -(-n // self.bucket) * self.bucket)

    def _admit(self, req: Request, slot: int) -> Optional[int]:
        """End-aligned admission: prefill ``req``'s prompt into ``slot``;
        returns its first token (None for an empty prompt — the first token
        then comes from the next decode step, fed from BOS).  Leaves
        ``_tok``/``_pos`` pointing at the next decode input."""
        prompt = np.asarray(req.prompt, np.int32)
        lp = int(prompt.shape[0])
        assert lp + req.gen <= self.max_len  # submit() validated
        if lp == 0:
            # no prompt: greedy generation starts from BOS at position 0 on a
            # fresh cache row — recurrent state leaves have no position
            # indexing, so the previous occupant's state must be zeroed (the
            # lp > 0 paths overwrite it via their prefill insert)
            self.cache = self._insert(self.cache,
                                      T.init_cache(self.cfg, 1, self._bucketed(1)),
                                      jnp.int32(slot))
            self._tok[slot], self._pos[slot] = self.bos, 0
            return None
        if self.fused:
            lb = self._bucketed(lp)
            toks = np.zeros((1, lb), np.int32)
            toks[0, :lp] = prompt
            batch = {"tokens": jnp.asarray(toks),
                     "length": jnp.asarray([lp], jnp.int32)}
            logits, row = self._prefill(self.params, batch,
                                        T.init_cache(self.cfg, 1, lb))
            if self.sampling:
                first = int(sample_tokens(logits, self._next_key(),
                                          self.temperature, self.top_p)[0])
            else:
                first = int(jnp.argmax(logits, axis=-1)[0])
        else:
            # recurrent state absorbs padding: unpadded per-token loop (B=1;
            # jit retraces per shape, so this reuses the decode step fn);
            # only the last prompt token's output matters — it is re-sampled
            # from its logits when sampling is on
            row = T.init_cache(self.cfg, 1, self._bucketed(lp))
            nxt = None
            for i in range(lp):
                if self.sampling and i == lp - 1:
                    lg, row = self._prefill_logits(
                        self.params, jnp.asarray(prompt[i:i + 1]), row,
                        jnp.int32(i))
                    nxt = sample_tokens(lg, self._next_key(),
                                        self.temperature, self.top_p)
                else:
                    nxt, row = self._decode_greedy(
                        self.params, jnp.asarray(prompt[i:i + 1]), row,
                        jnp.int32(i))
            first = int(nxt[0])
        self.cache = self._insert(self.cache, row, jnp.int32(slot))
        self._tok[slot], self._pos[slot] = first, lp
        return first

    def _admit_paged(self, req: Request, slot: int, st: _Slot) -> None:
        """Paged admission: reserve worst-case pages (so alloc-on-write can
        never fail mid-flight) and start the chunked prefill — no cache work
        happens here; pages are written chunk by chunk in the tick loop."""
        self.pool.admit(req.rid, len(req.prompt) + req.gen)
        self._tables[slot] = -1
        if len(req.prompt) == 0:
            # no prompt: decode from BOS at position 0; the fresh page is
            # allocated by the pre-decode ensure() and stale arena contents
            # beyond position 0 stay behind the kpos <= pos mask
            st.state = "decode"
            self._tok[slot], self._pos[slot] = self.bos, 0
            return
        st.state, st.cursor = "prefill", 0

    def _prefill_chunk_tick(self, slot: int, st: _Slot) -> Optional[int]:
        """Consume ONE ``chunk``-token slice of ``slot``'s prompt (the
        admission-stall bound: in-flight decodes wait for at most this one
        fixed-shape call per prefilling slot per tick).  Returns the first
        generated token when the prompt completes, else None."""
        prompt = np.asarray(st.req.prompt, np.int32)
        lp = int(prompt.shape[0])
        lo = st.cursor
        ln = min(self.chunk, lp - lo)
        self.pool.ensure(st.req.rid, lo + ln)
        toks = np.zeros((1, self.chunk), np.int32)
        toks[0, :ln] = prompt[lo:lo + ln]
        table = self.pool.table(st.req.rid, self.n_pages)[None]
        logits, self.cache = self._chunk_prefill(
            self.params, jnp.asarray(toks), self.cache, jnp.int32(lo),
            jnp.asarray(table), jnp.int32(ln))
        st.cursor = lo + ln
        if st.cursor < lp:
            return None
        st.state = "decode"
        if self.sampling:
            first = int(sample_tokens(logits, self._next_key(),
                                      self.temperature, self.top_p)[0])
        else:
            first = int(jnp.argmax(logits, axis=-1)[0])
        self._tok[slot], self._pos[slot] = first, lp
        return first

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request] = (), *,
            on_token: Optional[Callable[[int, int], None]] = None) -> dict:
        """Serve ``requests`` (plus anything already ``submit``ted) to
        completion.  Tokens stream per request through ``on_token(rid,
        token)`` (one host sync per engine tick).  Returns completions plus
        aggregate wall-time / throughput metrics (and the block pool's
        occupancy/fragmentation report in paged mode)."""
        for req in requests:
            self.submit(req)
        pending = deque(sorted(self._queue, key=lambda r: (r.arrival, r.rid)))
        self._queue = []
        active: Dict[int, _Slot] = {}
        free = list(range(self.slots - 1, -1, -1))
        done: Dict[int, Completion] = {}
        generated = 0
        tick = 0
        t0 = time.perf_counter()

        def finish(slot: int) -> None:
            st = active.pop(slot)
            free.append(slot)
            if self.paged:
                # eviction: pages return to the pool; the dead table row
                # makes any parked-slot writes drop on the device
                self.pool.free(st.req.rid)
                self._tables[slot] = -1
            done[st.req.rid] = Completion(
                rid=st.req.rid, tokens=st.tokens, arrival=st.req.arrival,
                admitted_tick=st.admitted_tick, done_tick=tick,
                admitted_s=st.admitted_s, first_token_s=st.first_token_s,
                done_s=time.perf_counter() - t0)

        def emit(slot: int, tok: int) -> None:
            nonlocal generated
            st = active[slot]
            if not st.tokens:
                st.first_token_s = time.perf_counter() - t0
            st.tokens.append(tok)
            generated += 1
            if on_token is not None:
                on_token(st.req.rid, tok)

        while pending or active:
            while pending and free and pending[0].arrival <= tick:
                if self.paged and not self.pool.can_admit(
                        len(pending[0].prompt) + pending[0].gen):
                    break          # FIFO head waits for pages to free up
                req = pending.popleft()
                slot = free.pop()
                st = _Slot(req=req, admitted_tick=tick,
                           admitted_s=time.perf_counter() - t0)
                active[slot] = st
                if self.paged:
                    self._admit_paged(req, slot, st)
                else:
                    first = self._admit(req, slot)
                    if first is not None:
                        emit(slot, first)
                        if len(st.tokens) >= req.gen:
                            finish(slot)
            if self.paged:
                # chunked prefill: one fixed-shape chunk per prefilling slot
                # per tick, interleaved with the decode tick below
                for slot in list(active):
                    st = active[slot]
                    if st.state != "prefill":
                        continue
                    first = self._prefill_chunk_tick(slot, st)
                    if first is not None:
                        emit(slot, first)
                        if len(st.tokens) >= st.req.gen:
                            finish(slot)
            decoding = [s for s, st in active.items() if st.state == "decode"]
            if not decoding:
                if active:
                    tick += 1      # prefill-only tick still advances time
                else:
                    # nothing resident: fast-forward the virtual clock
                    tick = pending[0].arrival if pending else tick + 1
                continue
            if self.paged:
                # alloc-on-write: this tick's token lands at pos, so each
                # decoding row's chain must cover pos+1 tokens (reserved at
                # admission — ensure can't fail); refresh the device tables
                for slot in decoding:
                    st = active[slot]
                    self.pool.ensure(st.req.rid, int(self._pos[slot]) + 1)
                    self._tables[slot] = self.pool.table(st.req.rid,
                                                         self.n_pages)
                args = (jnp.asarray(self._tok), self.cache,
                        jnp.asarray(self._pos), jnp.asarray(self._tables))
            else:
                args = (jnp.asarray(self._tok), self.cache,
                        jnp.asarray(self._pos))
            if self.sampling:
                nxt, self.cache = self._decode(self.params, *args,
                                               self._next_key())
            else:
                nxt, self.cache = self._decode(self.params, *args)
            nxt = np.asarray(nxt)               # host sync = the stream point
            tick += 1
            for slot in decoding:
                if slot not in active:
                    continue
                self._pos[slot] += 1
                self._tok[slot] = nxt[slot]
                emit(slot, int(nxt[slot]))
                if len(active[slot].tokens) >= active[slot].req.gen:
                    finish(slot)
        jax.block_until_ready(self.cache)
        wall = time.perf_counter() - t0
        out = {
            "completions": done,
            "generated": generated,
            "ticks": tick,
            "wall_s": wall,
            "tok_s": generated / wall if wall > 0 else float("inf"),
        }
        if self.paged:
            out["pool"] = self.pool.report()
        return out


def make_requests(n: int, prompt_len: int, gen: int, vocab: int, *,
                  stagger: int = 0, seed: int = 1) -> List[Request]:
    """Uniform synthetic request stream: ``n`` requests of ``prompt_len``
    random prompt tokens, ``gen`` outputs, arriving ``stagger`` ticks apart."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, vocab, (prompt_len,)).astype(np.int32),
                    gen=gen, arrival=i * stagger)
            for i in range(n)]
