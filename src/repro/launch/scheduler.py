"""Slot-based continuous-batching decode scheduler (the serving subsystem).

Design (ROADMAP "real-traffic serving path"):

  * A fixed pool of ``slots`` cache rows backs one fixed-shape jitted decode
    step ``decode(params, tok (B,), cache, pos (B,))``: the per-slot position
    vector lets every request advance independently, so new requests join and
    finished ones leave mid-flight without retracing.
  * Admission: when a slot is free and a request has arrived, its prompt runs
    as ONE fused cache-writing prefill call (``parallel.steps.
    make_prefill_step``) on a bucketed right-padded (1, Lb) batch — causal
    masking makes end-padding invisible — and the resulting cache rows are
    scattered into the slot.  Recurrent-family patterns (mamba2 / mlstm /
    slstm) absorb pad tokens into their state, so they fall back to a B=1
    per-token prefill loop instead.
  * Eviction: after ``gen`` greedy tokens the slot returns to the free list;
    a parked slot keeps riding the batched step (fixed shapes) but its writes
    land at its frozen position, which the next occupant either overwrites at
    prefill or hides behind the causal mask until decode overtakes it.
  * Arrivals are measured in engine ticks (decode steps), giving a
    deterministic, machine-independent arrival process; wall-clock is used
    only for the reported latency/throughput metrics.

Slots are end-aligned (no ring reuse): ``prompt_len + gen <= max_len`` per
request, and ``max_len <= cfg.window`` for sliding-window archs.

The naive one-request-at-a-time server is this same engine with ``slots=1``
— the A/B in ``benchmarks/_serve_throughput.py`` isolates exactly the
continuous-batching win.  Cost-model predictions for both sides come from
``costmodel.decode_step_cost`` / ``prefill_cost`` (``roofline --serve``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import ModelConfig, ParallelConfig
from repro.models import transformer as T
from repro.parallel import steps as S


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float,
                  top_p: float = 1.0) -> jax.Array:
    """Temperature / top-p (nucleus) sampling over ``(B, V)`` logits;
    ``temperature == 0`` is greedy argmax (the scheduler's default and the
    test oracle).  Top-p keeps the smallest prefix of the sorted
    distribution whose mass exceeds ``top_p`` (the top token always
    survives), masks the rest to -inf, and samples the renormalized tail."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # temperature first, nucleus second (the conventional order): the top-p
    # mass is measured on the tempered distribution, so raising T widens
    # the kept set
    logits = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < top_p            # mass before this token < p
        last = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)
        thresh = jnp.take_along_axis(sorted_l, last[..., None], axis=-1)
        logits = jnp.where(logits >= thresh, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: Sequence[int]          # token ids; may be empty (generate from BOS)
    gen: int                       # tokens to generate, >= 1
    arrival: int = 0               # engine tick at which the request appears


@dataclass
class Completion:
    rid: int
    tokens: List[int]
    arrival: int
    admitted_tick: int
    done_tick: int
    admitted_s: float              # wall seconds from run start
    first_token_s: float           # wall seconds from run start
    done_s: float

    @property
    def ttft_s(self) -> float:
        """Admission → first token (prefill latency; queue wait is virtual
        ticks, so pre-admission wall time is not a serving latency)."""
        return self.first_token_s - self.admitted_s


@dataclass
class _Slot:
    req: Request
    tokens: List[int] = field(default_factory=list)
    admitted_tick: int = 0
    admitted_s: float = 0.0
    first_token_s: float = 0.0


class Scheduler:
    """Continuous-batching greedy-decode engine over a fixed slot pool."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, params, *,
                 slots: int = 4, max_len: int = 256, bucket: int = 16,
                 bos: int = 0, ctx=None, temperature: float = 0.0,
                 top_p: float = 1.0, seed: int = 0):
        if cfg.enc_dec:
            raise NotImplementedError("enc-dec serving is not scheduled yet")
        if slots < 1 or max_len < 2:
            raise ValueError(f"need slots >= 1 and max_len >= 2, got "
                             f"{slots}/{max_len}")
        if temperature < 0.0 or not 0.0 < top_p <= 1.0:
            raise ValueError(f"need temperature >= 0 and 0 < top_p <= 1, "
                             f"got {temperature}/{top_p}")
        if cfg.window is not None and max_len > cfg.window:
            raise NotImplementedError(
                f"slots are end-aligned: max_len {max_len} must fit the "
                f"attention window {cfg.window}")
        if hasattr(pcfg, "to_pcfg"):          # a first-class ParallelPlan
            pcfg = pcfg.to_pcfg()
        self.cfg, self.pcfg, self.params, self.ctx = cfg, pcfg, params, ctx
        self.slots, self.max_len = slots, max_len
        self.bucket, self.bos = max(1, bucket), bos
        self.temperature, self.top_p, self.seed = temperature, top_p, seed
        self.sampling = temperature > 0.0
        self.fused = T.supports_fused_prefill(cfg)
        if self.sampling:
            # logits-returning decode + per-tick sampling, one fused jit:
            # every slot samples from its own row (parked rows ride along)
            base = S.make_decode_step(cfg, pcfg, ctx, return_logits=True)

            def _sampled(p, tok, cache, pos, key):
                logits, new_cache = base(p, tok, cache, pos)
                return sample_tokens(logits, key, temperature, top_p), new_cache

            self._decode = jax.jit(_sampled, donate_argnums=(2,))
        else:
            self._decode = jax.jit(S.make_decode_step(cfg, pcfg, ctx),
                                   donate_argnums=(2,))
        # unpadded per-token prefill fallback is always greedy-shaped (its
        # intermediate outputs are ignored; the last token is re-sampled)
        self._decode_greedy = self._decode if not self.sampling else \
            jax.jit(S.make_decode_step(cfg, pcfg, ctx), donate_argnums=(2,))
        self._prefill = jax.jit(S.make_prefill_step(cfg, pcfg, ctx),
                                donate_argnums=(2,)) if self.fused else None
        self._prefill_logits = jax.jit(
            S.make_decode_step(cfg, pcfg, ctx, return_logits=True),
            donate_argnums=(2,)) if self.sampling and not self.fused else None
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.reset()

    def reset(self) -> None:
        """Fresh cache + slot state (jit caches survive — use for warmup);
        the sampling stream restarts from the seed for reproducible runs."""
        self.cache = T.init_cache(self.cfg, self.slots, self.max_len)
        self._tok = np.zeros((self.slots,), np.int32)
        self._pos = np.zeros((self.slots,), np.int32)
        self._key = jax.random.PRNGKey(self.seed)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    @staticmethod
    def _insert_impl(big, small, slot):
        return jax.tree.map(
            lambda bg, sm: lax.dynamic_update_slice(
                bg, sm.astype(bg.dtype), (0, slot) + (0,) * (bg.ndim - 2)),
            big, small)

    # ------------------------------------------------------------------
    def _bucketed(self, n: int) -> int:
        return min(self.max_len, -(-n // self.bucket) * self.bucket)

    def _admit(self, req: Request, slot: int) -> Optional[int]:
        """Prefill ``req``'s prompt into ``slot``; returns its first greedy
        token (None for an empty prompt — the first token then comes from the
        next decode step, fed from BOS).  Leaves ``_tok``/``_pos`` pointing at
        the next decode input."""
        prompt = np.asarray(req.prompt, np.int32)
        lp = int(prompt.shape[0])
        if lp + req.gen > self.max_len:
            raise ValueError(f"request {req.rid}: prompt {lp} + gen {req.gen} "
                             f"exceeds max_len {self.max_len}")
        if lp == 0:
            # no prompt: greedy generation starts from BOS at position 0 on a
            # fresh cache row — recurrent state leaves have no position
            # indexing, so the previous occupant's state must be zeroed (the
            # lp > 0 paths overwrite it via their prefill insert)
            self.cache = self._insert(self.cache,
                                      T.init_cache(self.cfg, 1, self._bucketed(1)),
                                      jnp.int32(slot))
            self._tok[slot], self._pos[slot] = self.bos, 0
            return None
        if self.fused:
            lb = self._bucketed(lp)
            toks = np.zeros((1, lb), np.int32)
            toks[0, :lp] = prompt
            batch = {"tokens": jnp.asarray(toks),
                     "length": jnp.asarray([lp], jnp.int32)}
            logits, row = self._prefill(self.params, batch,
                                        T.init_cache(self.cfg, 1, lb))
            if self.sampling:
                first = int(sample_tokens(logits, self._next_key(),
                                          self.temperature, self.top_p)[0])
            else:
                first = int(jnp.argmax(logits, axis=-1)[0])
        else:
            # recurrent state absorbs padding: unpadded per-token loop (B=1;
            # jit retraces per shape, so this reuses the decode step fn);
            # only the last prompt token's output matters — it is re-sampled
            # from its logits when sampling is on
            row = T.init_cache(self.cfg, 1, self._bucketed(lp))
            nxt = None
            for i in range(lp):
                if self.sampling and i == lp - 1:
                    lg, row = self._prefill_logits(
                        self.params, jnp.asarray(prompt[i:i + 1]), row,
                        jnp.int32(i))
                    nxt = sample_tokens(lg, self._next_key(),
                                        self.temperature, self.top_p)
                else:
                    nxt, row = self._decode_greedy(
                        self.params, jnp.asarray(prompt[i:i + 1]), row,
                        jnp.int32(i))
            first = int(nxt[0])
        self.cache = self._insert(self.cache, row, jnp.int32(slot))
        self._tok[slot], self._pos[slot] = first, lp
        return first

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *,
            on_token: Optional[Callable[[int, int], None]] = None) -> dict:
        """Serve ``requests`` to completion.  Greedy tokens stream per request
        through ``on_token(rid, token)`` (one host sync per engine tick).
        Returns completions plus aggregate wall-time / throughput metrics."""
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        active: Dict[int, _Slot] = {}
        free = list(range(self.slots - 1, -1, -1))
        done: Dict[int, Completion] = {}
        generated = 0
        tick = 0
        t0 = time.perf_counter()

        def finish(slot: int) -> None:
            st = active.pop(slot)
            free.append(slot)
            done[st.req.rid] = Completion(
                rid=st.req.rid, tokens=st.tokens, arrival=st.req.arrival,
                admitted_tick=st.admitted_tick, done_tick=tick,
                admitted_s=st.admitted_s, first_token_s=st.first_token_s,
                done_s=time.perf_counter() - t0)

        def emit(slot: int, tok: int) -> None:
            nonlocal generated
            st = active[slot]
            if not st.tokens:
                st.first_token_s = time.perf_counter() - t0
            st.tokens.append(tok)
            generated += 1
            if on_token is not None:
                on_token(st.req.rid, tok)

        while pending or active:
            while pending and free and pending[0].arrival <= tick:
                req = pending.popleft()
                slot = free.pop()
                st = _Slot(req=req, admitted_tick=tick,
                           admitted_s=time.perf_counter() - t0)
                active[slot] = st
                first = self._admit(req, slot)
                if first is not None:
                    emit(slot, first)
                    if len(st.tokens) >= req.gen:
                        finish(slot)
            if not active:
                # nothing resident: fast-forward the virtual clock
                tick = pending[0].arrival if pending else tick + 1
                continue
            if self.sampling:
                nxt, self.cache = self._decode(
                    self.params, jnp.asarray(self._tok), self.cache,
                    jnp.asarray(self._pos), self._next_key())
            else:
                nxt, self.cache = self._decode(
                    self.params, jnp.asarray(self._tok), self.cache,
                    jnp.asarray(self._pos))
            nxt = np.asarray(nxt)               # host sync = the stream point
            tick += 1
            for slot in list(active):
                self._pos[slot] += 1
                self._tok[slot] = nxt[slot]
                emit(slot, int(nxt[slot]))
                if len(active[slot].tokens) >= active[slot].req.gen:
                    finish(slot)
        jax.block_until_ready(self.cache)
        wall = time.perf_counter() - t0
        return {
            "completions": done,
            "generated": generated,
            "ticks": tick,
            "wall_s": wall,
            "tok_s": generated / wall if wall > 0 else float("inf"),
        }


def make_requests(n: int, prompt_len: int, gen: int, vocab: int, *,
                  stagger: int = 0, seed: int = 1) -> List[Request]:
    """Uniform synthetic request stream: ``n`` requests of ``prompt_len``
    random prompt tokens, ``gen`` outputs, arriving ``stagger`` ticks apart."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, vocab, (prompt_len,)).astype(np.int32),
                    gen=gen, arrival=i * stagger)
            for i in range(n)]
