"""Serving launcher: batched prefill + decode loop (example application).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --requests 4 \
      --prompt-len 32 --gen 16

Runs a reduced config on CPU; the same driver serves the production mesh.
Requests are batched; prefill fills the KV cache (per-token loop kept simple
here — a production server would use the fused prefill path), then greedy
decode streams tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import ParallelConfig
from repro.launch.train import reduced
from repro.models import transformer as T
from repro.parallel import steps as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(configs.get(args.arch))
    if cfg.enc_dec:
        raise SystemExit("enc-dec serving: use examples/whisper_serve.py")
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)

    b = args.requests
    max_len = args.prompt_len + args.gen
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (b, args.prompt_len), 0, cfg.vocab)

    decode = jax.jit(S.make_decode_step(cfg, pcfg, None), donate_argnums=(2,))
    cache = T.init_cache(cfg, b, max_len)

    # prefill: feed prompt tokens through the decode path (cache warm-up)
    t0 = time.time()
    tok = prompts[:, 0]
    for i in range(args.prompt_len):
        nxt, cache = decode(params, prompts[:, i], cache, jnp.int32(i))
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    tok = nxt
    for i in range(args.gen):
        out.append(tok)
        tok, cache = decode(params, tok, cache, jnp.int32(args.prompt_len + i))
    jax.block_until_ready(tok)
    t_gen = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"served {b} requests: prefill {args.prompt_len} toks in "
          f"{t_prefill:.2f}s, generated {args.gen} toks in {t_gen:.2f}s "
          f"({b * args.gen / t_gen:.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
