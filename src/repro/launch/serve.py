"""Serving launcher: thin CLI over the continuous-batching scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --requests 4 \
      --prompt-len 32 --gen 16 --slots 4 --stagger 2

Runs a reduced config on CPU; the same driver serves the production mesh.
Each prompt is prefilled in ONE fused cache-writing forward (recurrent
families fall back to a per-token loop), then requests share a fixed slot
pool: staggered arrivals are admitted into free slots mid-flight, finished
requests evicted, greedy tokens streamed per request
(``launch/scheduler.py``).  ``--naive`` serves one request at a time
(slots=1) for an A/B against the batched engine.  ``--paged`` switches to
the paged KV-cache engine (``serving/kvcache.py``): admission becomes
chunked prefill (``--chunk`` tokens per tick) writing into ``--block``-token
pages of a shared arena, request length is bounded by pool capacity instead
of the per-slot row, and the end-of-run report includes the pool's
occupancy / fragmentation.  A warmup pass runs first so JIT compile time
never lands in the reported tok/s, and every timing reads after
``jax.block_until_ready``.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.launch.scheduler import Scheduler, make_requests
from repro.launch.train import reduced
from repro.models import transformer as T
from repro.parallel import planner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--stagger", type=int, default=2,
                    help="ticks (decode steps) between request arrivals")
    ap.add_argument("--naive", action="store_true",
                    help="one-request-at-a-time baseline (slots=1)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache engine: block-pool arena + chunked "
                         "prefill admission (pure-attention archs)")
    ap.add_argument("--block", type=int, default=16,
                    help="page size in tokens (only with --paged)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill tokens consumed per tick (only with --paged)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="total pages in the pool (default: slots x "
                         "ceil(max_len/block), the end-aligned memory)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy (the default "
                         "and the test oracle)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (only with --temperature > 0)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling stream seed (reproducible runs)")
    args = ap.parse_args()
    if args.requests < 1 or args.gen < 1:
        ap.error(f"--requests and --gen must be >= 1 "
                 f"(got {args.requests}/{args.gen})")
    if args.prompt_len < 0 or args.slots < 1 or args.stagger < 0:
        ap.error("--prompt-len/--stagger must be >= 0 and --slots >= 1")
    if args.block < 1 or args.chunk < 1 or \
            (args.pool_blocks is not None and args.pool_blocks < 1):
        ap.error("--block/--chunk/--pool-blocks must be >= 1")
    if args.temperature < 0 or not 0 < args.top_p <= 1:
        ap.error("--temperature must be >= 0 and --top-p in (0, 1]")
    if args.prompt_len + args.gen < 2:
        ap.error("--prompt-len + --gen must be >= 2 (the slot pool needs a "
                 "cache of at least two positions)")

    cfg = reduced(configs.get(args.arch))
    if cfg.enc_dec:
        raise SystemExit("enc-dec serving: use examples/whisper_serve.py")
    if args.paged and not T.supports_paged(cfg):
        raise SystemExit(f"--paged needs a pure-attention no-SWA arch; "
                         f"{cfg.name} has pattern {cfg.block_pattern} "
                         f"(window={cfg.window})")
    # single-host CPU layout as a first-class plan (the scheduler bridges it)
    plan = planner.ParallelPlan(mesh_shape=(1, 1), fsdp_axes=(), tp=1,
                                grad="none", remat="none")
    params = T.init(jax.random.PRNGKey(0), cfg)

    slots = 1 if args.naive else args.slots
    max_len = args.prompt_len + args.gen
    if not args.paged and cfg.window is not None and max_len > cfg.window:
        raise SystemExit(f"prompt+gen {max_len} exceeds the reduced "
                         f"attention window {cfg.window} (end-aligned slots; "
                         f"--paged lifts the limit for no-SWA archs)")
    sched = Scheduler(cfg, plan, params, slots=slots, max_len=max_len,
                      temperature=args.temperature, top_p=args.top_p,
                      seed=args.seed, paged=args.paged, block=args.block,
                      chunk=args.chunk, pool_blocks=args.pool_blocks)

    # warmup: compile prefill/decode/insert outside the timed run
    sched.run(make_requests(min(2, args.requests), args.prompt_len,
                            min(2, args.gen), cfg.vocab))
    sched.reset()

    reqs = make_requests(args.requests, args.prompt_len, args.gen, cfg.vocab,
                         stagger=args.stagger)
    out = sched.run(reqs)
    comps = out["completions"]
    assert len(comps) == args.requests, (len(comps), args.requests)
    mode = "naive (1 slot)" if args.naive else f"batched ({slots} slots)"
    if args.paged:
        mode += f", paged (block={args.block} chunk={args.chunk} " \
                f"pool={sched.pool.n_blocks})"
    if args.temperature > 0:
        mode += f", T={args.temperature} top_p={args.top_p}"
    ttft = sorted(c.ttft_s for c in comps.values())
    print(f"served {args.requests} requests [{mode}, fused_prefill="
          f"{sched.fused}]: {out['generated']} toks in {out['wall_s']:.2f}s "
          f"({out['tok_s']:.1f} tok/s, {out['ticks']} ticks)")
    print(f"ttft (admission->first token) p50/p99: "
          f"{ttft[len(ttft) // 2] * 1e3:.1f}/"
          f"{ttft[int(len(ttft) * 0.99)] * 1e3:.1f} ms")
    if args.paged:
        rep = out["pool"]
        print(f"pool: {rep['n_blocks']} blocks x {rep['block']} toks, peak "
              f"occupancy {rep['peak_occupancy']:.2f}, end occupancy "
              f"{rep['occupancy']:.2f}, internal fragmentation at peak "
              f"{rep['frag_at_peak']:.2f}")
    print("sample:", comps[0].tokens[:12])


if __name__ == "__main__":
    main()
