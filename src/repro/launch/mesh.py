"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 16×16 = 256 chips, axes (data, model).  Multi-pod:
2×16×16 = 512 chips, axes (pod, data, model); ``pod`` maps to the DCI link
class in the cost model.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
