"""Compiled-HLO analysis: collective traffic + roofline terms.

``compiled.cost_analysis()`` reports **per-device** FLOPs/bytes for the SPMD
partitioned module (verified empirically); we multiply by chip count when
reporting "global HLO_FLOPs" so the spec formula
``compute = HLO_FLOPs / (chips × peak)`` applies literally.

collective_bytes is parsed from ``compiled.as_text()`` (post-partitioning, so
shapes are per-device).  Each op contributes its modeled per-device *wire*
traffic on a ring/torus:

  all-reduce        2·m·(p−1)/p     (reduce-scatter + all-gather)
  all-gather        m_out·(p−1)/p
  reduce-scatter    m_out·(p−1)
  all-to-all        m·(p−1)/p
  collective-permute m

(m = per-device result bytes, p = replica-group size).  The raw Σ result
bytes is also recorded.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|[su]\d+|c\d+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _result_bytes(line: str, op_start: int) -> int:
    """Sum bytes of array literals in the result type: the segment between
    '=' and the op name (handles tuple results of async collectives)."""
    eq = line.find("=")
    if eq < 0:
        return 0
    seg = line[eq + 1: op_start]
    total = 0
    for dt, dims in _ARRAY_RE.findall(seg):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def collective_stats(hlo_text: str, n_devices: int) -> Dict:
    """Per-op-kind counts, raw result bytes, and modeled wire bytes."""
    stats = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
             for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        kind = m.group(1)
        mb = _result_bytes(line, m.start(1))
        p = _group_size(line, n_devices)
        if kind == "all-reduce":
            wire = 2.0 * mb * (p - 1) / p
        elif kind == "all-gather":
            wire = mb * (p - 1) / p
        elif kind == "reduce-scatter":
            wire = mb * (p - 1)
        elif kind == "all-to-all":
            wire = mb * (p - 1) / p
        else:  # collective-permute
            wire = float(mb)
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += mb
        s["wire_bytes"] += wire
    total_wire = sum(s["wire_bytes"] for s in stats.values())
    total_raw = sum(s["result_bytes"] for s in stats.values())
    return {"per_op": stats, "wire_bytes": total_wire, "result_bytes": total_raw}


def analyze_compiled(compiled, n_devices: int) -> Dict:
    """All dry-run artifacts for one cell: memory, flops, collectives."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # jax<=0.4.x wraps it in a list
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_stats(txt, n_devices)
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    return {
        "chips": n_devices,
        "flops_per_device": flops_dev,
        "hlo_flops_global": flops_dev * n_devices,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
    }
