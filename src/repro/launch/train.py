"""Training launcher: end-to-end driver (example application (b)).

On the CPU container this trains a reduced config on a small local mesh; on
a real cluster the same entry point runs the production mesh (the step
function, sharding rules, and checkpoint path are identical — only the mesh
size changes).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --steps 50 \
      --reduce --batch 8 --seq 256

Fault tolerance is on by default: step-fenced checkpoints + crash-only
restart loop (runtime/recovery.py); ``--inject-fault-at N`` proves recovery.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import (ModelConfig, ParallelConfig, ShapeConfig, TrainConfig)
from repro.data import make_batch_iterator
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.parallel import planner
from repro.parallel import steps as S
from repro.parallel.sharding import make_ctx, param_specs, to_shardings
from repro.runtime import TrainingRunner
from repro import checkpoint as ckpt


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink an arch config to a CPU-trainable size, same family/topology."""
    import dataclasses
    kw = dict(n_layers=len(cfg.block_pattern), d_model=128, n_heads=4,
              n_kv_heads=min(4, cfg.n_kv_heads), d_ff=256 if cfg.d_ff else 0,
              vocab=512, head_dim=32)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_ff_expert=128)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=32)
    if cfg.window:
        kw["window"] = 64
    return cfg.replace(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    # BooleanOptionalAction so --no-reduce can actually turn it off (the old
    # store_true + default=True pair made the flag impossible to disable)
    ap.add_argument("--reduce", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--plan", default="default",
                    choices=["default", "auto", "zero", "allreduce"],
                    help="parallel layout: 'auto' runs the cost-model "
                         "plan_search on the local mesh; zero/allreduce pin "
                         "the gradient strategy")
    # 3e-3 (with the seeded init/data below) descends within even 8-step
    # smoke runs; 1e-3 needs tens of steps to clear the warmup ramp
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-fault-at", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    shape = ShapeConfig("train_cli", "train", args.seq, args.batch)
    n_dev = len(jax.devices())
    mesh = make_local_mesh(model=args.model_parallel)
    if args.plan == "auto":
        # cost-driven layout on the local mesh (a ParallelPlan, ranked by
        # the Table-1 step model); top feasible point wins
        ranked = planner.plan_search(
            cfg, tuple(mesh.shape[a] for a in mesh.axis_names),
            args.batch, args.seq, "train",
            axis_names=tuple(mesh.axis_names))
        plan = planner.best_plan(ranked)   # same f32-moments numerics guard
        top = next(r for r in ranked if r.plan is plan)
        print(f"plan_search picked: {plan.label()} "
              f"(predicted {top.total_s * 1e3:.2f} ms/step)")
        pcfg = plan.to_pcfg()
    else:
        grad = {"zero": "reduce_scatter_zero"}.get(args.plan, "all_reduce")
        pcfg = ParallelConfig(remat="none", fsdp_params=False,
                              grad_reduce=grad)
    # warmup must fit inside short smoke runs (the fault-injection test does 8
    # steps) or the effective lr never leaves the ramp and the loss plateaus
    warmup = max(1, min(10, args.steps // 4))
    tcfg = TrainConfig(lr=args.lr, warmup_steps=warmup, total_steps=args.steps,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir, z_loss=0.0)

    ctx = make_ctx(mesh, pcfg) if n_dev > 1 else None

    train_step = S.make_train_step(cfg, pcfg, tcfg, ctx)
    jitted = jax.jit(train_step, donate_argnums=(0,))

    def build(start_step: int):
        if ckpt.latest_step(args.ckpt_dir):
            like = S.abstract_train_state(cfg, pcfg)
            state = ckpt.restore_checkpoint(args.ckpt_dir, start_step, like)
        else:
            state = S.init_train_state(jax.random.PRNGKey(tcfg.seed), cfg, pcfg)
        batches = make_batch_iterator(cfg, shape, seed=tcfg.seed,
                                      start_step=start_step)
        return state, jitted, batches

    runner = TrainingRunner(directory=args.ckpt_dir, build=build,
                            checkpoint_every=args.ckpt_every)
    t0 = time.time()
    state, history = runner.run(args.steps, inject_fault_at=args.inject_fault_at)
    dt = time.time() - t0
    losses = [h["loss"] for h in history]
    print(f"\ntrained {len(history)} steps in {dt:.1f}s "
          f"({dt / max(len(history), 1):.3f}s/step)")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
