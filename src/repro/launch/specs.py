"""ShapeDtypeStruct input stand-ins + sharding trees for every
(architecture × input shape) cell — the dry-run's data layer.

Nothing here allocates: abstract params via ``jax.eval_shape``, inputs as
``ShapeDtypeStruct``.  Modality frontends are stubs per the assignment:
whisper gets (B, 1500, d_model) frame embeddings, chameleon gets VQ token
ids (they live in the text vocab).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models import encdec as E
from repro.models.encdec import ENC_LEN
from repro.models.moe import MeshCtx
from repro.parallel.sharding import make_ctx, param_specs, to_shardings

Pytree = Any


def make_cell_ctx(mesh: Mesh, pcfg: ParallelConfig, global_batch: int) -> MeshCtx:
    """MeshCtx whose batch axes are restricted to those that divide the
    global batch (B=1 long-decode ⇒ batch replicated, model axis carries
    all parallelism — see EXPERIMENTS §Roofline discussion)."""
    ctx = make_ctx(mesh, pcfg)
    axes: Tuple[str, ...] = ()
    prod = 1
    for a in ctx.batch_axes:
        if global_batch % (prod * mesh.shape[a]) == 0:
            axes += (a,)
            prod *= mesh.shape[a]
    return MeshCtx(mesh=mesh, batch_axes=axes, model_axis=ctx.model_axis,
                   fsdp_axes=ctx.fsdp_axes, moe_a2a_ep=ctx.moe_a2a_ep,
                   engine_replicate=ctx.engine_replicate,
                   seq_parallel=ctx.seq_parallel, foopar_tp=ctx.foopar_tp,
                   manual_attention=ctx.manual_attention,
                   dp_over_model=ctx.dp_over_model)


def _bspec(ctx: MeshCtx, ndim: int, batch_dim: int = 0) -> P:
    parts: list = [None] * ndim
    parts[batch_dim] = ctx.batch_axes if ctx.batch_axes else None
    return P(*parts)


def _div(n: int, size: int, axis: str) -> Optional[str]:
    return axis if n % size == 0 else None


def cache_specs(cfg: ModelConfig, ctx: MeshCtx, cache: Pytree) -> Pytree:
    """PartitionSpec tree for a decode cache pytree: batch over batch axes,
    heads/channels over 'model' where divisible."""
    msz = ctx.model_size
    model = ctx.model_axis

    def leaf(path, x):
        # shapes: (periods, B, ...) — dim1 batch
        parts: list = [None] * x.ndim
        if x.ndim >= 2:
            parts[1] = ctx.batch_axes if ctx.batch_axes else None
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if "attn" in names or "shared_attn" in names:
            # (periods, B, L, Hkv, hd): cache LENGTH over model (heads rarely
            # divide TP under GQA; decode attention shards the L dim)
            parts[2] = _div(x.shape[2], msz, model)
        elif "mamba" in names and "conv" in names:
            parts[3] = _div(x.shape[3], msz, model)       # channels
        elif "mamba" in names and "ssm" in names:
            parts[2] = _div(x.shape[2], msz, model)       # heads
        elif "mlstm" in names:
            parts[2] = _div(x.shape[2], msz, model)
        elif "slstm" in names:
            parts[2] = _div(x.shape[2], msz, model)       # channels (d)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf, cache)


@dataclass
class Cell:
    """Everything the dry-run needs for one (arch × shape × mesh) cell."""
    cfg: ModelConfig
    shape: ShapeConfig
    ctx: MeshCtx
    abstract_args: tuple          # ShapeDtypeStructs for the step fn
    in_shardings: tuple
    kind: str                     # train | prefill | decode


def _abs(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    init = E.init_cache if cfg.enc_dec else T.init_cache
    return jax.eval_shape(lambda: init(cfg, batch, max_len))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               pcfg: ParallelConfig) -> Cell:
    """Abstract inputs + shardings for one cell (state excluded — the caller
    pairs these with abstract_train_state / abstract params)."""
    ctx = make_cell_ctx(mesh, pcfg, shape.global_batch)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        batch = {"tokens": _abs((b, s), jnp.int32)}
        bsh = {"tokens": NamedSharding(mesh, _bspec(ctx, 2))}
        if cfg.enc_dec:
            batch["frames"] = _abs((b, ENC_LEN, cfg.d_model), jnp.float32)
            bsh["frames"] = NamedSharding(mesh, _bspec(ctx, 3))
        return Cell(cfg, shape, ctx, (batch,), (bsh,), "train")

    if shape.kind == "prefill":
        batch = {"tokens": _abs((b, s), jnp.int32)}
        bsh = {"tokens": NamedSharding(mesh, _bspec(ctx, 2))}
        if cfg.enc_dec:
            batch["frames"] = _abs((b, ENC_LEN, cfg.d_model), jnp.float32)
            bsh["frames"] = NamedSharding(mesh, _bspec(ctx, 3))
        # fused prefill writes the prompt's KV/state cache in-pass
        cache = abstract_cache(cfg, b, s)
        csh = to_shardings(cache_specs(cfg, ctx, cache), mesh)
        return Cell(cfg, shape, ctx, (batch, cache), (bsh, csh), "prefill")

    # decode: one new token against a seq_len cache
    cache = abstract_cache(cfg, b, s)
    csh = to_shardings(cache_specs(cfg, ctx, cache), mesh)
    token = _abs((b,), jnp.int32)
    tsh = NamedSharding(mesh, _bspec(ctx, 1))
    pos = _abs((), jnp.int32)
    psh = NamedSharding(mesh, P())
    args = [token, cache, pos]
    shs = [tsh, csh, psh]
    if cfg.enc_dec:
        args.append(_abs((b, ENC_LEN, cfg.d_model), jnp.float32))
        shs.append(NamedSharding(mesh, _bspec(ctx, 3)))
    return Cell(cfg, shape, ctx, tuple(args), tuple(shs), "decode")
