"""Unit tests for the compiled-HLO collective parser (roofline input)."""
from repro.launch.hlo_analysis import collective_stats, _result_bytes, _OP_RE


HLO = """
ENTRY %main {
  %ar = f32[16,4096,3072]{2,1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[256,256,3072]{2,1,0} all-gather(%y), replica_groups=[1,16]<=[16], dimensions={0}
  %rs = f32[16,256]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-to-all(%u, %v), replica_groups=[2,8]<=[16]
  %cp = bf16[64,64]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ard = f32[4]{0} all-reduce-done(%ar2)
  %ars = (f32[16]{0}, f32[16]{0}) all-reduce-start(%q), replica_groups=[1,4]<=[4]
}
"""


def test_collective_stats_counts_and_bytes():
    st = collective_stats(HLO, 256)
    per = st["per_op"]
    # all-reduce: one sync (16*4096*3072*4 bytes) + one -start (2*16*4)
    ar_sync = 16 * 4096 * 3072 * 4
    assert per["all-reduce"]["count"] == 2
    assert per["all-reduce"]["result_bytes"] == ar_sync + 2 * 16 * 4
    # group size parsed from [16,16]<=[256] => p=16
    expected_wire = 2 * ar_sync * 15 / 16
    assert abs(per["all-reduce"]["wire_bytes"] -
               (expected_wire + 2 * (2 * 16 * 4) * 3 / 4)) < 1.0
    # all-gather
    ag = 256 * 256 * 3072 * 2
    assert per["all-gather"]["result_bytes"] == ag
    assert abs(per["all-gather"]["wire_bytes"] - ag * 15 / 16) < 1.0
    # reduce-scatter with explicit groups {{0,1,2,3}} => p=4
    rs = 16 * 256 * 4
    assert per["reduce-scatter"]["wire_bytes"] == rs * 3
    # tuple-result all-to-all counts both halves
    assert per["all-to-all"]["result_bytes"] == 2 * 8 * 128 * 4
    # permute = raw bytes
    assert per["collective-permute"]["wire_bytes"] == 64 * 64 * 2
    # -done line ignored
    assert st["wire_bytes"] > 0


def test_result_bytes_tuple():
    line = "  %x = (bf16[2,4]{1,0}, f32[3]{0}) all-to-all(%a, %b), replica_groups=[1,2]<=[2]"
    m = _OP_RE.search(line)
    assert _result_bytes(line, m.start(1)) == 2 * 4 * 2 + 3 * 4
