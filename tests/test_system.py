"""End-to-end behaviour tests: training convergence, serving, sharding rules,
dry-run cell construction."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ParallelConfig, ShapeConfig, TrainConfig, SHAPES
from repro.data import make_batch_iterator
from repro.launch.train import reduced
from repro.parallel import steps as S
from repro.models import transformer as T


def test_training_loss_decreases():
    """30 steps on the structured synthetic stream must cut the loss well
    below the start (the every-token-repeated rule is learnable)."""
    cfg = reduced(configs.get("llama3.2-3b")).replace(vocab=64)
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=40, z_loss=0.0)
    shape = ShapeConfig("t", "train", 64, 4)
    step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, None), donate_argnums=(0,))
    state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
    losses = []
    it = make_batch_iterator(cfg, shape)
    for i, batch in zip(range(30), it):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_serve_loop_greedy_decode():
    cfg = reduced(configs.get("chatglm3-6b"))
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    decode = jax.jit(S.make_decode_step(cfg, pcfg, None), donate_argnums=(2,))
    b, n = 2, 8
    cache = T.init_cache(cfg, b, n)
    tok = jnp.zeros((b,), jnp.int32)
    outs = []
    for i in range(n):
        tok, cache = decode(params, tok, cache, jnp.int32(i))
        outs.append(np.asarray(tok))
    assert all(o.shape == (b,) for o in outs)
    assert all((o >= 0).all() and (o < cfg.vocab).all() for o in outs)


def test_param_spec_rules_cover_all_archs():
    """Every arch's full-size param tree gets a valid, divisible spec on the
    production mesh (structural check — no allocation)."""
    from repro.parallel.sharding import param_specs
    from repro.models.moe import MeshCtx
    from repro.models import encdec as E
    from repro.core.compat import abstract_mesh
    from jax.sharding import PartitionSpec as P

    mesh = abstract_mesh((16, 16), ("data", "model"))
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        init = E.init if cfg.enc_dec else T.init
        params = jax.eval_shape(lambda init=init, cfg=cfg:
                                init(jax.random.PRNGKey(0), cfg))
        ctx = MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                      fsdp_axes=("data",))
        specs = param_specs(params, cfg, ctx)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 9):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % size == 0, (arch, leaf.shape, spec)


def test_build_cell_all_40():
    """All 40 (arch × shape) cells construct abstract inputs + shardings."""
    from repro.launch.specs import build_cell
    from repro.core.compat import abstract_mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    n = 0
    for arch, shape_name, skip in configs.cells():
        n += 1
        if skip:
            continue
        cfg = configs.get(arch)
        cell = build_cell(cfg, SHAPES[shape_name], mesh, ParallelConfig())
        assert cell.abstract_args
    assert n == 40


@pytest.mark.slow
def test_train_launcher_with_fault_injection():
    """The CLI driver completes despite an injected node failure."""
    import shutil
    shutil.rmtree("/tmp/repro_test_fault", ignore_errors=True)  # no stale resume
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "chatglm3-6b",
         "--steps", "8", "--batch", "2", "--seq", "64", "--ckpt-every", "3",
         "--ckpt-dir", "/tmp/repro_test_fault", "--inject-fault-at", "5"],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.join(__import__("os").path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
