import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
sys.path.insert(0, __import__("os").path.join(__import__("os").path.dirname(__file__), "..", "..", "src"))
from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.moe import MeshCtx

cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=4, d_ff=64, vocab=64, dtype="float32")
p = L.mlp_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
ref = L.mlp(p, x, cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = MeshCtx(mesh=mesh, batch_axes=("data",), foopar_tp=True)
got = jax.jit(lambda p, x: L.mlp(p, x, cfg, ctx=ctx))(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)
# grads flow
g = jax.jit(jax.grad(lambda p: jnp.sum(L.mlp(p, x, cfg, ctx=ctx)**2)))(p)
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
print("FOOPAR_TP_OK")
