"""Multi-device DSeq algebra checks (run in a subprocess: needs 8 fake devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.core import DSeq, spmd, make_grid_mesh
from repro.core.dseq import scan_d

mesh = make_grid_mesh((8,), ("x",))
x = jnp.arange(8.0 * 4).reshape(8, 4)


def body(xl):
    s = DSeq(xl[0], "x")
    return (s.reduceD("sum"), s.reduceD(lambda a, b: a + b),
            s.reduceD(jnp.minimum), s.shiftD(3).local[None],
            s.allGatherD(), s.apply(5), s.scanD().local[None])


f = spmd(body, mesh, in_specs=P("x", None),
         out_specs=(P(None), P(None), P(None), P("x", None), P(None, None),
                    P(None), P("x", None)))
rs, rt, rm, sh, g, bc, sc = f(x)
np.testing.assert_allclose(rs, x.sum(0))
np.testing.assert_allclose(rt, x.sum(0))
np.testing.assert_allclose(rm, x.min(0))
np.testing.assert_allclose(np.asarray(sh), np.roll(np.asarray(x), 3, axis=0))
np.testing.assert_allclose(g, x)
np.testing.assert_allclose(bc, x[5])
np.testing.assert_allclose(np.asarray(sc), np.concatenate(
    [np.zeros((1, 4)), np.cumsum(np.asarray(x), 0)[:-1]]))

# reduceD to a specific root: non-root entries are zero
def body2(xl):
    return DSeq(xl[0], "x").reduceD(lambda a, b: a + b, root=3)[None]

r = spmd(body2, mesh, in_specs=P("x", None), out_specs=P("x", None))(x)
np.testing.assert_allclose(np.asarray(r)[3], x.sum(0))
assert np.all(np.asarray(r)[[0, 1, 2, 4, 5, 6, 7]] == 0)

# allToAllD == transpose of the process-data mapping
def body3(xl):
    return DSeq(xl.reshape(8, 1), "x").allToAllD().local.reshape(1, 8)

y = spmd(body3, mesh, in_specs=P("x", None), out_specs=P("x", None))(
    jnp.arange(64.0).reshape(8, 8))
np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.arange(64.0).reshape(8, 8)).T)

# non-power-of-two group (tree reduce remainder handling)
mesh6 = jax.make_mesh((6,), ("x",), devices=jax.devices()[:6])
x6 = jnp.arange(6.0 * 3).reshape(6, 3)
r6 = spmd(lambda xl: DSeq(xl[0], "x").reduceD(lambda a, b: a + b), mesh6,
          in_specs=P("x", None), out_specs=P(None))(x6)
np.testing.assert_allclose(r6, x6.sum(0), rtol=1e-6)

print("DSEQ_OK")
