"""Property checks for the arXiv:1406.6163 collectives (scanD,
reduceScatterD, ringShiftD, allGatherRingD) against their dense oracles on
4- and 8-process groups (run in a subprocess: needs 8 fake devices).

Uses hypothesis when installed; otherwise falls back to a fixed seed sweep
so the properties are still exercised in minimal environments.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.core import spmd
from repro.core.dseq import (all_gather_ring_d, reduce_scatter_d, ring_shift_d,
                             scan_d)

MESHES = {p: jax.make_mesh((p,), ("x",), devices=jax.devices()[:p])
          for p in (4, 8)}
_cache = {}


def _fn(key, p, build):
    """Jit once per (program, group size); hypothesis re-invokes with data."""
    if (key, p) not in _cache:
        _cache[(key, p)] = jax.jit(build(MESHES[p]))
    return _cache[(key, p)]


def check_scan(p: int, seed: int) -> None:
    x = jnp.array(np.random.RandomState(seed).randn(p, 5), jnp.float32)
    inc = _fn("inc", p, lambda m: spmd(
        lambda xl: scan_d(xl[0], "x", inclusive=True)[None], m,
        in_specs=P("x", None), out_specs=P("x", None)))
    np.testing.assert_allclose(np.asarray(inc(x)), np.cumsum(np.asarray(x), 0),
                               rtol=1e-5, atol=1e-5)
    exc = _fn("exc", p, lambda m: spmd(
        lambda xl: scan_d(xl[0], "x")[None], m,
        in_specs=P("x", None), out_specs=P("x", None)))
    want = np.concatenate([np.zeros((1, 5)), np.cumsum(np.asarray(x), 0)[:-1]])
    np.testing.assert_allclose(np.asarray(exc(x)), want, rtol=1e-5, atol=1e-5)
    mx = _fn("max", p, lambda m: spmd(
        lambda xl: scan_d(xl[0], "x", jnp.maximum, inclusive=True)[None], m,
        in_specs=P("x", None), out_specs=P("x", None)))
    np.testing.assert_allclose(np.asarray(mx(x)),
                               np.maximum.accumulate(np.asarray(x), 0), rtol=1e-5)


def check_reduce_scatter(p: int, seed: int) -> None:
    # rank r holds x[r] (a (p, 5) slab); the reduced sequence reshaped over
    # ranks must equal the psum oracle: chunk i of sum_r x[r] lands on rank i.
    x = jnp.array(np.random.RandomState(seed).randn(p, p, 5), jnp.float32)
    want = np.asarray(x).sum(0).reshape(p, 1, 5)
    for name, op in (("rs_sum", "sum"), ("rs_gen", lambda a, b: a + b)):
        f = _fn(name, p, lambda m, op=op: spmd(
            lambda xl: reduce_scatter_d(xl[0], op, "x")[None], m,
            in_specs=P("x", None, None), out_specs=P("x", None, None)))
        np.testing.assert_allclose(np.asarray(f(x)), want, rtol=1e-4, atol=1e-5)


def check_ring(p: int, seed: int) -> None:
    x = jnp.array(np.random.RandomState(seed).randn(p, 5), jnp.float32)
    sh = _fn("ring", p, lambda m: spmd(
        lambda xl: ring_shift_d(xl[0], "x")[None], m,
        in_specs=P("x", None), out_specs=P("x", None)))
    np.testing.assert_allclose(np.asarray(sh(x)),
                               np.roll(np.asarray(x), 1, axis=0), rtol=1e-6)
    ag = _fn("ag", p, lambda m: spmd(
        lambda xl: all_gather_ring_d(xl[0], "x"), m,
        in_specs=P("x", None), out_specs=P(None, None)))
    np.testing.assert_allclose(np.asarray(ag(x)), np.asarray(x), rtol=1e-6)


def run_all(p: int, seed: int) -> None:
    check_scan(p, seed)
    check_reduce_scatter(p, seed)
    check_ring(p, seed)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(p=st.sampled_from([4, 8]), seed=st.integers(0, 1000))
    def prop(p, seed):
        run_all(p, seed)

    prop()
except ImportError:
    for p in (4, 8):
        for seed in range(3):
            run_all(p, seed)

print("COLLECTIVES_OK")
