"""Multi-device paper-algorithm checks (subprocess: 8 fake devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.core import (dns_matmul, dns_matmul_pallas, generic_matmul,
                        floyd_warshall, blocked_floyd_warshall,
                        floyd_warshall_reference, make_grid_mesh)

rng = np.random.RandomState(0)

# DNS (Grid3D) matmul, 2x2x2 grid
mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))
n = 32
A = jnp.array(rng.randn(n, n), jnp.float32)
B = jnp.array(rng.randn(n, n), jnp.float32)
np.testing.assert_allclose(np.asarray(dns_matmul(A, B, mesh3)),
                           np.asarray(A @ B), rtol=1e-3, atol=1e-4)

# DNS with the Pallas local-multiply kernel (interpret mode)
np.testing.assert_allclose(np.asarray(dns_matmul_pallas(A, B, mesh3)),
                           np.asarray(A @ B), rtol=1e-3, atol=1e-3)

# generic (Algorithm 1) with the for-loop emulation, 8-process group
np.testing.assert_allclose(
    np.asarray(generic_matmul(A, B, make_grid_mesh((8,), ("z",)), axis="z")),
    np.asarray(A @ B), rtol=1e-3, atol=1e-4)

# Floyd-Warshall, 2x2 grid (n=24)
mesh2 = make_grid_mesh((2, 2), ("x", "y"))
n = 24
W = rng.rand(n, n).astype(np.float32) * 10
W[np.diag_indices(n)] = 0
D = jnp.array(W)
ref = floyd_warshall_reference(D)
np.testing.assert_allclose(np.asarray(floyd_warshall(D, mesh2)),
                           np.asarray(ref), rtol=1e-5)
np.testing.assert_allclose(np.asarray(blocked_floyd_warshall(D, mesh2)),
                           np.asarray(ref), rtol=1e-5)

# FooPar TP matmuls (algebra inside pjit)
from repro.core.tensor_ops import foopar_matmul_row, foopar_matmul_col, dns_matmul_2d
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jnp.array(rng.randn(16, 8), jnp.float32)
w = jnp.array(rng.randn(8, 12), jnp.float32)
ref = np.asarray(x) @ np.asarray(w)
for fn in (foopar_matmul_row, foopar_matmul_col, dns_matmul_2d):
    got = jax.jit(lambda a, b, fn=fn: fn(a, b, mesh=mesh))(x, w)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4)

print("ALGOS_OK")
