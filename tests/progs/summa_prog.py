"""SUMMA + Cannon vs the jnp.matmul oracle on square (2x2) and rectangular
(2x4) grids, including the Pallas local-multiply path and the cost-model
sanity ties (run in a subprocess: needs 8 fake devices).

Uses hypothesis when installed; otherwise a fixed seed sweep.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.core import cannon_matmul, costmodel, summa_matmul

MESHES = {
    (2, 2): jax.make_mesh((2, 2), ("x", "y"), devices=jax.devices()[:4]),
    (2, 4): jax.make_mesh((2, 4), ("x", "y")),
}
_cache = {}


def _fn(alg, grid):
    if (alg, grid) not in _cache:
        mesh = MESHES[grid]
        fn = summa_matmul if alg == "summa" else cannon_matmul
        _cache[(alg, grid)] = jax.jit(lambda a, b: fn(a, b, mesh))
    return _cache[(alg, grid)]


def check(grid, seed: int, n: int = 16) -> None:
    rng = np.random.RandomState(seed)
    A = jnp.array(rng.randn(n, n), jnp.float32)
    B = jnp.array(rng.randn(n, n), jnp.float32)
    want = np.asarray(A) @ np.asarray(B)
    for alg in ("summa", "cannon"):
        got = np.asarray(_fn(alg, grid)(A, B))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(grid=st.sampled_from([(2, 2), (2, 4)]), seed=st.integers(0, 1000))
    def prop(grid, seed):
        check(grid, seed)

    prop()
except ImportError:
    for grid in ((2, 2), (2, 4)):
        for seed in range(3):
            check(grid, seed)

# rectangular operands: (m, k) @ (k, n) with m≠k≠n
rng = np.random.RandomState(7)
A = jnp.array(rng.randn(8, 32), jnp.float32)
B = jnp.array(rng.randn(32, 16), jnp.float32)
want = np.asarray(A) @ np.asarray(B)
for grid in ((2, 2), (2, 4)):
    for alg in ("summa", "cannon"):
        fn = summa_matmul if alg == "summa" else cannon_matmul
        got = np.asarray(jax.jit(lambda a, b, f=fn, m=MESHES[grid]: f(a, b, m))(A, B))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

# Pallas MXU kernel as the local multiply (interpret mode on CPU)
from repro.core import cannon_matmul_pallas, summa_matmul_pallas

A = jnp.array(rng.randn(16, 16), jnp.float32)
B = jnp.array(rng.randn(16, 16), jnp.float32)
want = np.asarray(A) @ np.asarray(B)
np.testing.assert_allclose(np.asarray(summa_matmul_pallas(A, B, MESHES[(2, 2)])),
                           want, rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(np.asarray(cannon_matmul_pallas(A, B, MESHES[(2, 2)])),
                           want, rtol=1e-3, atol=1e-3)

# cost-model ties: predicted communication of Cannon never exceeds SUMMA's on
# the same square grid (no broadcast trees), and both cover the same flops
for n, q in ((1024, 2), (4096, 8)):
    cs = costmodel.summa_matmul_cost(n, q)
    cc = costmodel.cannon_matmul_cost(n, q)
    assert cc["compute_s"] == cs["compute_s"]
    assert cc["shift_s"] <= cs["broadcast_s"] * (1 + 1e-9), (cc, cs)

print("SUMMA_OK")
