"""SUMMA + Cannon + pipelined SUMMA + 2.5D Cannon vs the jnp.matmul oracle
on square (2x2), rectangular (2x4), and replicated (2x2x2) grids, including
the Pallas local-multiply path and the cost-model sanity ties (run in a
subprocess: needs 8 fake devices).

Uses hypothesis when installed; otherwise a fixed seed sweep.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.core import (cannon_matmul, cannon_matmul_25d, costmodel,
                        summa_matmul, summa_matmul_pipelined)

MESHES = {
    (2, 2): jax.make_mesh((2, 2), ("x", "y"), devices=jax.devices()[:4]),
    (2, 4): jax.make_mesh((2, 4), ("x", "y")),
    (2, 2, 2): jax.make_mesh((2, 2, 2), ("x", "y", "z")),
}
ALGS = {"summa": summa_matmul, "cannon": cannon_matmul,
        "summa_pipelined": summa_matmul_pipelined,
        "cannon_25d": cannon_matmul_25d}
_cache = {}


def _algs_for(grid):
    # 2.5D needs the q x q x c mesh; the 2D algorithms a 2-axis one
    return ("cannon_25d",) if len(grid) == 3 else (
        "summa", "cannon", "summa_pipelined")


def _fn(alg, grid):
    if (alg, grid) not in _cache:
        mesh = MESHES[grid]
        fn = ALGS[alg]
        _cache[(alg, grid)] = jax.jit(lambda a, b: fn(a, b, mesh))
    return _cache[(alg, grid)]


def check(grid, seed: int, n: int = 16) -> None:
    rng = np.random.RandomState(seed)
    A = jnp.array(rng.randn(n, n), jnp.float32)
    B = jnp.array(rng.randn(n, n), jnp.float32)
    want = np.asarray(A) @ np.asarray(B)
    for alg in _algs_for(grid):
        got = np.asarray(_fn(alg, grid)(A, B))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(grid=st.sampled_from([(2, 2), (2, 4), (2, 2, 2)]),
           seed=st.integers(0, 1000))
    def prop(grid, seed):
        check(grid, seed)

    prop()
except ImportError:
    for grid in ((2, 2), (2, 4), (2, 2, 2)):
        for seed in range(3):
            check(grid, seed)

# rectangular operands: (m, k) @ (k, n) with m≠k≠n
rng = np.random.RandomState(7)
A = jnp.array(rng.randn(8, 32), jnp.float32)
B = jnp.array(rng.randn(32, 16), jnp.float32)
want = np.asarray(A) @ np.asarray(B)
for grid in ((2, 2), (2, 4), (2, 2, 2)):
    for alg in _algs_for(grid):
        got = np.asarray(_fn(alg, grid)(A, B))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

# ring-broadcast helpers ≡ tree broadcast (both row- and column-wise, every
# source): the pipelined primitive delivers exactly what apply_d does
from jax.sharding import PartitionSpec as P
from repro.core import spmd
from repro.core.grid import Grid2D

mesh24 = MESHES[(2, 4)]
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
for src_col in range(4):
    def body(lx, s=src_col):
        g = Grid2D()
        st = g.bcast_row_ring_start(lx, s)
        for _ in range(3):          # q_y - 1 hops
            st = g.bcast_row_ring_next(st)
        return st.value - g.bcast_row(lx, s)
    diff = spmd(body, mesh24, in_specs=(P("x", "y"),),
                out_specs=P("x", "y"))(x)
    assert not np.asarray(diff).any(), (src_col, diff)
for src_row in range(2):
    def body(lx, s=src_row):
        g = Grid2D()
        st = g.bcast_col_ring_start(lx, s)
        st = g.bcast_col_ring_next(st)  # q_x - 1 = 1 hop
        assert st.done
        return st.value - g.bcast_col(lx, s)
    diff = spmd(body, mesh24, in_specs=(P("x", "y"),),
                out_specs=P("x", "y"))(x)
    assert not np.asarray(diff).any(), (src_row, diff)

# Pallas local multiply (interpret mode on CPU); the wrappers now use the
# accumulate-in-place MXU kernel so the panel loop updates C in one buffer
from repro.core import (cannon_matmul_25d_pallas, cannon_matmul_pallas,
                        summa_matmul_pallas, summa_matmul_pipelined_pallas)

A = jnp.array(rng.randn(16, 16), jnp.float32)
B = jnp.array(rng.randn(16, 16), jnp.float32)
want = np.asarray(A) @ np.asarray(B)
np.testing.assert_allclose(np.asarray(summa_matmul_pallas(A, B, MESHES[(2, 2)])),
                           want, rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(np.asarray(cannon_matmul_pallas(A, B, MESHES[(2, 2)])),
                           want, rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(
    np.asarray(summa_matmul_pipelined_pallas(A, B, MESHES[(2, 4)])),
    want, rtol=1e-3, atol=1e-3)
np.testing.assert_allclose(
    np.asarray(cannon_matmul_25d_pallas(A, B, MESHES[(2, 2, 2)])),
    want, rtol=1e-3, atol=1e-3)

# cost-model ties: predicted communication of Cannon never exceeds SUMMA's on
# the same square grid (no broadcast trees), both cover the same flops, and
# overlap pipelining only ever helps on the grids it targets
for n, q in ((1024, 2), (4096, 8)):
    cs = costmodel.summa_matmul_cost(n, q)
    cc = costmodel.cannon_matmul_cost(n, q)
    assert cc["compute_s"] == cs["compute_s"]
    assert cc["shift_s"] <= cs["broadcast_s"] * (1 + 1e-9), (cc, cs)
for n, qx, qy in ((512, 2, 4), (1024, 2, 2)):
    cs = costmodel.summa_matmul_cost(n, qx, qy)
    cp = costmodel.summa_pipelined_cost(n, qx, qy)
    assert cp["total_s"] <= cs["total_s"] * (1 + 1e-9), (cp, cs)

print("SUMMA_OK")
