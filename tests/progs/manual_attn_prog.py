import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
sys.path.insert(0, __import__("os").path.join(__import__("os").path.dirname(__file__), "..", "..", "src"))
from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.moe import MeshCtx

cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype="float32", window=None)
p = L.attention_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
pos = jnp.arange(16)
ref, _ = L.attention(p, x, pos, cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = MeshCtx(mesh=mesh, batch_axes=("data",), manual_attention=True)
got, _ = jax.jit(lambda p, x: L.attention(p, x, pos, cfg, ctx=ctx))(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-3, atol=1e-4)
# SWA too
cfg2 = cfg.replace(window=4)
ref2, _ = L.attention(p, x, pos, cfg2)
got2, _ = jax.jit(lambda p, x: L.attention(p, x, pos, cfg2, ctx=ctx))(p, x)
np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2), rtol=1e-3, atol=1e-4)
print("MANUAL_ATTN_OK")
