"""Oracle: the ZeRO reduce-scatter train step matches the all-reduce step's
training trajectory on a 1×8 CPU mesh (f32 end to end).

The two steps share every numeric op — loss, grads, clip (taken on the
reduced grads *before* the scatter) and the per-element AdamW math — so the
loss trajectory must agree bit-for-bit in f32; the updated params may differ
by reduction-layout ulps (all-gathered shard vs replicated update), bounded
tightly.  Also asserts the layout actually scattered: optimizer moments live
as 1/8 shards, and the plan lattice only offers the zero strategy where
there is a group to scatter over.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro import configs
from repro.config import ParallelConfig, ShapeConfig, TrainConfig
from repro.data import make_batch_iterator
from repro.launch.train import reduced
from repro.parallel import steps as S
from repro.parallel.sharding import make_ctx, param_specs, scatter_specs

STEPS = 6


def run(grad: str, mesh, cfg, tcfg):
    pcfg = ParallelConfig(remat="none", fsdp_params=False,
                          grad_dtype="float32", grad_reduce=grad)
    ctx = make_ctx(mesh, pcfg)
    state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
    sh = S.train_state_shardings(cfg, pcfg, ctx, state)
    state = jax.device_put(state, sh)
    bsh = {"tokens": NamedSharding(mesh, P(("data",), None))}
    step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, ctx),
                   in_shardings=(sh, bsh), out_shardings=(sh, None),
                   donate_argnums=(0,))
    losses = []
    it = make_batch_iterator(cfg, ShapeConfig("t", "train", 64, 8))
    for _, batch in zip(range(STEPS), it):
        state, m = step(state, jax.device_put(batch, bsh))
        losses.append(float(m["loss"]))
    return losses, state


def main():
    assert len(jax.devices()) == 8
    cfg = reduced(configs.get("llama3.2-3b")).replace(
        vocab=64, dtype="float32", param_dtype="float32")
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=20, z_loss=0.0)

    losses_ar, state_ar = run("all_reduce", mesh, cfg, tcfg)
    losses_z, state_z = run("reduce_scatter_zero", mesh, cfg, tcfg)

    # trajectory: bit-for-bit in f32
    assert losses_ar == losses_z, (losses_ar, losses_z)
    assert losses_ar[-1] < losses_ar[0], losses_ar

    # params: all-gathered shard update ≡ replicated update (layout ulps only)
    for a, b in zip(jax.tree.leaves(jax.device_get(state_ar["params"])),
                    jax.tree.leaves(jax.device_get(state_z["params"]))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-6)

    # the zero layout really is scattered: at least one moment leaf stores a
    # strict 1/8 shard per device...
    scattered = sum(
        1 for leaf in jax.tree.leaves(state_z["opt"]["m"])
        if np.prod(leaf.addressable_shards[0].data.shape) * 8
        == np.prod(leaf.shape))
    assert scattered > 0, "no optimizer moment was reduce-scattered"
    # ... while the all-reduce layout keeps full replicas (model axis is 1)
    for leaf in jax.tree.leaves(state_ar["opt"]["m"]):
        assert leaf.addressable_shards[0].data.shape == leaf.shape

    # scatter_specs sanity on the same tree: fsdp-off specs gain the data
    # axis on a divisible dim; indivisible leaves stay put
    ctx = make_ctx(mesh, ParallelConfig(remat="none", fsdp_params=False))
    params = jax.device_get(state_ar["params"])
    sspec = scatter_specs(params, cfg, ctx)
    pspec = param_specs(params, cfg, ctx)
    changed = sum(1 for s, p_ in zip(jax.tree.leaves(sspec, is_leaf=lambda x: isinstance(x, P)),
                                     jax.tree.leaves(pspec, is_leaf=lambda x: isinstance(x, P)))
                  if s != p_)
    assert changed > 0, "scatter_specs added no scatter axes"

    print("ZERO_OK")


if __name__ == "__main__":
    main()
