"""MoE expert-parallel shard_map path vs single-device oracle (subprocess)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
from repro.config import ModelConfig, MoEConfig
from repro.models.moe import moe_init, moe_ffn, MeshCtx

cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=4, d_ff=64, vocab=64, block_pattern=("attn_moe",),
                  dtype="float32",
                  moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                                n_shared_experts=1, capacity_factor=8.0))
params = moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32))

ref, ref_probs = moe_ffn(params, x, cfg, None)  # single-device oracle

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
              fsdp_axes=("data",))

# EP layout: 8 experts over 4 shards (capacity_factor high => no drops)
got, probs = jax.jit(lambda p, x: moe_ffn(p, x, cfg, ctx))(params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2, atol=2e-3)
np.testing.assert_allclose(np.asarray(probs), np.asarray(ref_probs), rtol=1e-3, atol=1e-5)

# TP layout: 3 experts < 4 shards (dropless)
cfg2 = ModelConfig(name="t2", family="moe", n_layers=1, d_model=32, n_heads=4,
                   n_kv_heads=4, d_ff=64, vocab=64, block_pattern=("attn_moe",),
                   dtype="float32",
                   moe=MoEConfig(n_experts=3, top_k=2, d_ff_expert=16))
p2 = moe_init(jax.random.PRNGKey(2), cfg2)
ref2, _ = moe_ffn(p2, x, cfg2, None)
got2, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg2, ctx))(p2, x)
np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2), rtol=2e-2, atol=2e-3)

# gradients flow through the sharded path
def loss(p):
    out, _ = moe_ffn(p, x, cfg, ctx)
    return jnp.sum(out ** 2)

g = jax.jit(jax.grad(loss))(params)
gn = jax.tree.reduce(lambda a, b: a + b,
                     jax.tree.map(lambda t: float(jnp.sum(jnp.abs(t))), g))
assert np.isfinite(gn) and gn > 0, gn

print("MOE_OK")

# a2a token-routing EP (§Perf H6) matches the oracle too
ctx3 = MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
               fsdp_axes=(), moe_a2a_ep=True)
got3, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg, ctx3))(params, x)
np.testing.assert_allclose(np.asarray(got3), np.asarray(ref), rtol=2e-2, atol=2e-3)
print("A2A_OK")
