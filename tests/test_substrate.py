"""Substrate tests: optimizer, schedule, data pipeline, checkpointing,
fault-tolerant runner."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro import checkpoint as ckpt
from repro.data.pipeline import SyntheticTokens
from repro.runtime import StepWatchdog, ElasticPlan


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    p = {"a": jnp.array([1.0, -2.0, 3.0]), "nested": {"b": jnp.ones((2, 2))}}
    g = jax.tree.map(lambda x: 0.1 * jnp.ones_like(x), p)
    st = optim.adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    newp, newst = optim.adamw_update(g, st, p, lr=lr, b1=b1, b2=b2,
                                     weight_decay=wd)
    # reference for the matrix leaf (decay applies, ndim>1)
    m = 0.1 * 0.1
    v = 0.05 * 0.01
    mh, vh = m / 0.1, v / 0.05
    delta = mh / (np.sqrt(vh) + eps) + wd * 1.0
    np.testing.assert_allclose(np.asarray(newp["nested"]["b"]),
                               1.0 - lr * delta, rtol=1e-5)
    # vector leaf: no weight decay
    delta_v = mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.asarray(newp["a"])[0], 1.0 - lr * delta_v,
                               rtol=1e-5)
    assert int(newst["step"]) == 1


def test_adamw_bf16_states():
    p = {"w": jnp.ones((4, 4))}
    st = optim.adamw_init(p, state_dtype="bfloat16")
    assert st["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.5)}
    newp, newst = optim.adamw_update(g, st, p, lr=0.01)
    assert newst["v"]["w"].dtype == jnp.bfloat16
    assert np.all(np.asarray(newp["w"]) < 1.0)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(1000.0), rtol=1e-5)
    np.testing.assert_allclose(float(optim.global_norm(clipped)), 1.0, rtol=1e-4)


def test_schedule_shape():
    lrs = [float(optim.warmup_cosine(jnp.int32(s), lr=1.0, warmup_steps=10,
                                     total_steps=100)) for s in range(100)]
    assert lrs[0] == pytest.approx(0.1) and abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2 and all(l >= 0 for l in lrs)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_elastic():
    ds = SyntheticTokens(vocab=100, seq_len=32, global_batch=8, seed=3)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1, b2)          # deterministic
    assert not np.array_equal(b1, ds.batch_at(6))  # step-dependent
    # elastic: host slices agree with the global batch at any split
    np.testing.assert_array_equal(ds.batch_at(5, 2, 6), b1[2:6])
    np.testing.assert_array_equal(
        np.concatenate([ds.batch_at(5, 0, 4), ds.batch_at(5, 4, 8)]), b1)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.int32(7)},
            "layers": ({"a": jnp.ones((2,))}, {"a": jnp.zeros((2,))})}
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 7, tree)
    assert ckpt.latest_step(d) == 7
    restored = ckpt.restore_checkpoint(d, 7, tree)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 tree, restored)


def test_checkpoint_async_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    saver = ckpt.AsyncCheckpointer(d)
    tree = {"x": jnp.ones((4,))}
    saver.save(10, tree)
    saver.save(20, jax.tree.map(lambda t: t * 2, tree))
    saver.wait()
    assert ckpt.latest_step(d) == 20
    r = ckpt.restore_checkpoint(d, 20, tree)
    np.testing.assert_array_equal(np.asarray(r["x"]), 2 * np.ones(4))


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------
def test_watchdog_detects_straggler():
    w = StepWatchdog(k=6.0, min_steps=5)
    jitter = np.random.RandomState(0)  # seeded: unseeded draws can cluster
    for _ in range(20):                # tightly and turn the 6-MAD gate flaky
        assert not w.observe(0.1 + jitter.rand() * 0.001)
    assert w.observe(1.0)


def test_elastic_plan_meshes():
    plan = ElasticPlan(model=1)
    m = plan.mesh_for(len(jax.devices()))
    assert m.shape["model"] == 1


def test_training_runner_recovers_from_fault(tmp_path):
    """Injected failure at step 7 → restart from the step-5 checkpoint →
    final state identical to an uninterrupted run (bitwise-reproducible
    pipeline + step-fenced checkpoints)."""
    from repro.runtime import TrainingRunner
    from repro.config import ParallelConfig, TrainConfig, ShapeConfig
    from repro.parallel import steps as S
    from repro.data import make_batch_iterator
    from repro.launch.train import reduced
    from repro import configs

    cfg = reduced(configs.get("llama3.2-3b")).replace(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        head_dim=16)
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10, z_loss=0.0)
    shape = ShapeConfig("t", "train", 32, 2)
    step = jax.jit(S.make_train_step(cfg, pcfg, tcfg, None))

    def make_build(ckdir):
        def build(start):
            if ckpt.latest_step(ckdir):
                like = S.abstract_train_state(cfg, pcfg)
                state = ckpt.restore_checkpoint(ckdir, start, like)
            else:
                state = S.init_train_state(jax.random.PRNGKey(0), cfg, pcfg)
            return state, step, make_batch_iterator(cfg, shape, start_step=start)
        return build

    d1 = str(tmp_path / "faulty")
    r1 = TrainingRunner(directory=d1, build=make_build(d1), checkpoint_every=5)
    s1, h1 = r1.run(10, inject_fault_at=7)

    d2 = str(tmp_path / "clean")
    r2 = TrainingRunner(directory=d2, build=make_build(d2), checkpoint_every=5)
    s2, h2 = r2.run(10)

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5,
            atol=1e-6),
        s1["params"], s2["params"])
