"""Paged KV-cache subsystem tests.

Three layers, matching the subsystem's structure:
  * kernel: ``kernels.paged_attention`` (Pallas, interpret mode) and its
    pure-jnp reference vs the dense ``models.layers._sdpa`` oracle,
    including GQA groups and a partially-filled last page;
  * allocator: ``serving.BlockPool`` invariants under random staggered
    admit/grow/free interleavings (hypothesis when installed, a seeded
    sweep otherwise — same fallback idiom as tests/progs);
  * scheduler: the paged engine's greedy tokens are identical to the
    end-aligned engine's for requests that fit both, and it serves
    requests the end-aligned engine must reject at submit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ParallelConfig
from repro.core import costmodel
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention, paged_attention_pallas
from repro.launch.scheduler import Request, Scheduler
from repro.launch.train import reduced
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving import BlockPool, PoolExhausted


def tiny(arch="llama3.2-3b", **kw):
    return reduced(configs.get(arch)).replace(
        dtype="float32", param_dtype="float32", vocab=64, **kw)


@pytest.fixture(scope="module")
def llama():
    cfg = tiny()
    return cfg, T.init(jax.random.PRNGKey(0), cfg)


PCFG = ParallelConfig(remat="none", fsdp_params=False)


# ---------------------------------------------------------------------------
# Kernel oracle: page view ≡ dense attention over the gathered sequence
# ---------------------------------------------------------------------------
def _paged_case(seed, b, hkv, rep, hd, n_blocks, blk, pages):
    """Random arena + per-request chains with garbage in unused blocks and
    beyond each row's valid length (masking must hide both), plus -1 tail
    table entries.  Lengths exercise the partially-filled last page."""
    rng = np.random.RandomState(seed)
    q = rng.randn(b, hkv, rep, hd).astype(np.float32)
    k = rng.randn(n_blocks, blk, hkv, hd).astype(np.float32)
    v = rng.randn(n_blocks, blk, hkv, hd).astype(np.float32)
    perm = rng.permutation(n_blocks)
    tables = np.full((b, pages), -1, np.int32)
    lengths = np.zeros((b,), np.int32)
    used = 0
    for row in range(b):
        # row 0 fills every page exactly; later rows end mid-page
        lengths[row] = pages * blk if row == 0 else rng.randint(1, pages * blk)
        chain = -(-int(lengths[row]) // blk)
        tables[row, :chain] = perm[used:used + chain]
        used += chain
    assert used <= n_blocks
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(tables), jnp.asarray(lengths))


def _dense_oracle(q, k, v, tables, lengths):
    """Gather each chain into a dense (B, L, Hkv, hd) cache and run the
    model's own ``_sdpa`` with the valid-length mask."""
    b = q.shape[0]
    blk = k.shape[1]
    lmax = tables.shape[1] * blk
    kd = np.zeros((b,) + (lmax,) + k.shape[2:], np.float32)
    vd = np.zeros_like(kd)
    for row in range(b):
        for j, t in enumerate(np.asarray(tables[row])):
            if t >= 0:
                kd[row, j * blk:(j + 1) * blk] = np.asarray(k)[t]
                vd[row, j * blk:(j + 1) * blk] = np.asarray(v)[t]
    out = L._sdpa(q[:, None], jnp.asarray(kd), jnp.asarray(vd), causal=False,
                  window=None, q_offset=0, kv_len_valid=lengths)
    return out[:, 0]


@pytest.mark.parametrize("rep", [1, 4])           # MHA and a 4-wide GQA group
def test_paged_ref_matches_dense_sdpa(rep):
    case = _paged_case(0, b=3, hkv=2, rep=rep, hd=16, n_blocks=12, blk=4,
                       pages=3)
    got = ref.paged_attention(*case)
    want = _dense_oracle(*case)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("rep", [1, 4])
def test_paged_pallas_matches_ref(rep):
    case = _paged_case(1, b=2, hkv=2, rep=rep, hd=16, n_blocks=10, blk=4,
                       pages=4)
    got = paged_attention_pallas(*case, interpret=True)
    want = ref.paged_attention(*case)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    # the auto-dispatch entry must agree too (ref backend off-TPU)
    auto = paged_attention(*case)
    np.testing.assert_allclose(auto, want, atol=1e-6, rtol=1e-6)


def test_paged_pallas_dead_rows_are_finite():
    """A row whose table is all -1 (parked/free slot) must produce finite
    output (the safe-divide path), not NaN that could poison downstream."""
    q, k, v, tables, lengths = _paged_case(2, b=2, hkv=2, rep=2, hd=8,
                                           n_blocks=6, blk=4, pages=2)
    tables = tables.at[1].set(-1)
    out = paged_attention_pallas(q, k, v, tables, lengths, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    out_ref = ref.paged_attention(q, k, v, tables, lengths)
    np.testing.assert_allclose(out[0], out_ref[0], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# BlockPool allocator invariants
# ---------------------------------------------------------------------------
def _check_invariants(pool: BlockPool):
    live = [blkid for chain in pool._pages.values() for blkid in chain]
    assert len(live) == len(set(live)), "a block is aliased by two chains"
    assert sorted(live + pool._free) == list(range(pool.n_blocks)), \
        "free list + live chains must partition the pool"
    for rid, chain in pool._pages.items():
        assert len(chain) <= pool._reserved[rid]
    assert pool.reserved_blocks <= pool.n_blocks


def _drive_pool(ops, n_blocks=16, block=4):
    """Replay an op sequence against a pool, checking invariants after every
    step.  ops: list of (kind, value) with kind in admit/grow/free."""
    pool = BlockPool(n_blocks, block)
    live = {}                                    # rid -> (tokens, total)
    next_rid = 0
    for kind, value in ops:
        if kind == "admit":
            total = 1 + value % (n_blocks * block)
            if pool.can_admit(total):
                pool.admit(next_rid, total)
                live[next_rid] = [0, total]
                next_rid += 1
            else:
                with pytest.raises(PoolExhausted):
                    pool.admit(next_rid, total)
                next_rid += 1                    # rid burned, not admitted
        elif kind == "grow" and live:
            rid = sorted(live)[value % len(live)]
            cur, total = live[rid]
            tokens = min(cur + 1 + value % block, total)
            chain = pool.ensure(rid, tokens)
            assert len(chain) == pool.blocks_needed(tokens) or tokens == 0
            live[rid][0] = tokens
            # the fixed-width table row mirrors the chain, -1 tail
            row = pool.table(rid, pool.n_blocks)
            assert list(row[:len(chain)]) == chain
            assert all(row[len(chain):] == -1)
        elif kind == "free" and live:
            rid = sorted(live)[value % len(live)]
            pool.free(rid)
            del live[rid]
        _check_invariants(pool)
    for rid in sorted(live):
        pool.free(rid)
        _check_invariants(pool)
    assert pool.live_blocks == 0 and pool.free_blocks == pool.n_blocks


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "free"]),
                              st.integers(0, 10 ** 6)), max_size=60))
    def test_block_pool_random_interleavings(ops):
        """Staggered alloc/free never aliases live pages; the free list
        conserves blocks; reservations never oversubscribe."""
        _drive_pool(ops)
except ImportError:                              # seeded fallback sweep
    def test_block_pool_random_interleavings():
        rng = np.random.RandomState(0)
        for _ in range(50):
            ops = [(["admit", "grow", "free"][rng.randint(3)],
                    int(rng.randint(10 ** 6)))
                   for _ in range(rng.randint(1, 60))]
            _drive_pool(ops)


def test_block_pool_units():
    pool = BlockPool(4, 8)
    assert pool.blocks_needed(1) == 1 and pool.blocks_needed(8) == 1
    assert pool.blocks_needed(9) == 2
    pool.admit(0, 20)                            # reserves 3 of 4
    assert not pool.can_admit(9) and pool.can_admit(8)
    with pytest.raises(PoolExhausted):
        pool.admit(1, 9)
    pool.ensure(0, 5)
    with pytest.raises(PoolExhausted):           # beyond the reservation
        pool.ensure(0, 25)
    rep = pool.report()
    assert rep["live_blocks"] == 1 and rep["reserved_blocks"] == 3
    assert rep["occupancy"] == 0.25
    assert rep["internal_frag"] == pytest.approx(1 - 5 / 8)
    pool.free(0)
    assert pool.report()["occupancy"] == 0.0
    assert pool.report()["peak_occupancy"] == 0.25
    with pytest.raises(ValueError):
        BlockPool(0, 8)


# ---------------------------------------------------------------------------
# Scheduler: paged engine vs the end-aligned oracle
# ---------------------------------------------------------------------------
def test_paged_tokens_identical_to_end_aligned(llama):
    """For requests that fit both engines, paged greedy output is
    token-identical to the end-aligned engine's — chunked prefill through
    pages computes the same sequence the fused end-aligned prefill does
    (heterogeneous staggered mix incl. an empty prompt; chunk chosen to
    leave a partial final slice, block to leave a partial last page)."""
    cfg, params = llama
    rng = np.random.RandomState(7)
    spec = [(5, 3, 0), (2, 4, 0), (7, 2, 1), (0, 3, 3)]
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab, (lp,)).astype(np.int32),
                    gen=gen, arrival=arr)
            for i, (lp, gen, arr) in enumerate(spec)]

    ea = Scheduler(cfg, PCFG, params, slots=2, max_len=16, bucket=8)
    out_ea = ea.run(reqs)
    pg = Scheduler(cfg, PCFG, params, slots=2, max_len=16, paged=True,
                   block=4, chunk=3)
    out_pg = pg.run(reqs)
    for i, (lp, gen, _) in enumerate(spec):
        assert out_pg["completions"][i].tokens == out_ea["completions"][i].tokens, i
        assert len(out_pg["completions"][i].tokens) == gen
    # eviction drained the pool; the run used it
    assert out_pg["pool"]["occupancy"] == 0.0
    assert out_pg["pool"]["peak_occupancy"] > 0.0


def test_paged_final_chunk_pad_overflow_does_not_corrupt(llama):
    """Regression: the final right-padded chunk's pad positions can run past
    the block-table width; an unguarded gather CLAMPS to the last (live)
    table entry and scatters pad K/V over real prompt tokens.  chunk=9 /
    block=4 / prompt=13 / max_len=16 puts pad tpos 16 and 17 one page past
    the 4-wide table."""
    cfg, params = llama
    rng = np.random.RandomState(5)
    req = Request(rid=0, prompt=rng.randint(0, cfg.vocab, (13,)).astype(np.int32),
                  gen=3)
    ea = Scheduler(cfg, PCFG, params, slots=1, max_len=16).run([req])
    pg = Scheduler(cfg, PCFG, params, slots=1, max_len=16, paged=True,
                   block=4, chunk=9).run([req])
    assert pg["completions"][0].tokens == ea["completions"][0].tokens


def test_paged_serves_beyond_end_aligned_capacity(llama):
    """The acceptance scenario: same total cache memory (pool_blocks*block
    == slots*max_len tokens), but prompt+gen exceeds the per-slot row — the
    end-aligned engine must reject at submit; the paged engine serves it
    and matches an end-aligned oracle given a big-enough slot."""
    cfg, params = llama
    rng = np.random.RandomState(11)
    big = Request(rid=0, prompt=rng.randint(0, cfg.vocab, (10,)).astype(np.int32),
                  gen=6)                          # 16 tokens > max_len 8

    ea = Scheduler(cfg, PCFG, params, slots=2, max_len=8)
    with pytest.raises(ValueError, match="end-aligned slot capacity"):
        ea.submit(big)

    pg = Scheduler(cfg, PCFG, params, slots=2, max_len=16, paged=True,
                   block=4, pool_blocks=4, chunk=4)   # 4*4 == 2*8 tokens
    out = pg.run([big])
    oracle = Scheduler(cfg, PCFG, params, slots=1, max_len=20)
    ref_toks = oracle.run([Request(rid=0, prompt=big.prompt, gen=6)])
    assert out["completions"][0].tokens == ref_toks["completions"][0].tokens
    assert out["pool"]["peak_occupancy"] == 1.0   # it genuinely needed the pool


def test_submit_validates_with_named_limits(llama):
    """Satellite: length validation happens at submit() time with an error
    naming the limit — pool-capacity-based in paged mode."""
    cfg, params = llama
    ea = Scheduler(cfg, PCFG, params, slots=1, max_len=8)
    with pytest.raises(ValueError, match=r"max_len=8"):
        ea.submit(Request(rid=0, prompt=np.zeros(6, np.int32), gen=5))
    with pytest.raises(ValueError, match="gen >= 1"):
        ea.submit(Request(rid=1, prompt=np.zeros(2, np.int32), gen=0))

    pg = Scheduler(cfg, PCFG, params, slots=1, max_len=64, paged=True,
                   block=4, pool_blocks=8, chunk=4)
    with pytest.raises(ValueError, match=r"pool capacity is 8 blocks"):
        pg.submit(Request(rid=2, prompt=np.zeros(40, np.int32), gen=8))
    with pytest.raises(ValueError, match="block-table width"):
        pg.submit(Request(rid=3, prompt=np.zeros(60, np.int32), gen=8))
    # a fitting request passes and runs from the queue
    pg.submit(Request(rid=4, prompt=np.zeros(3, np.int32), gen=2))
    out = pg.run()
    assert list(out["completions"]) == [4]


def test_paged_requires_pure_attention():
    cfg = tiny("xlstm-1.3b").replace(block_pattern=("mlstm",), n_layers=1)
    params = T.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError, match="pure-attention"):
        Scheduler(cfg, PCFG, params, slots=1, max_len=8, paged=True)
    with pytest.raises(NotImplementedError):
        T.init_paged_cache(cfg, 4, 4)


# ---------------------------------------------------------------------------
# Cost model: page-gather tax and the chunked-prefill stall tradeoff
# ---------------------------------------------------------------------------
def test_paged_decode_cost_converges_to_dense():
    n, b, kvb, kvt = 3e9, 32, 2 ** 20, 2 ** 12
    dense = costmodel.decode_step_cost(n, b, kvb)
    prev = None
    for blk in (8, 64, 512, 2 ** 20):
        paged = costmodel.paged_decode_step_cost(n, b, kvb, block=blk,
                                                 kv_token_bytes=kvt)
        assert paged["total_s"] >= dense["total_s"] - 1e-12
        if prev is not None:
            assert paged["total_s"] <= prev + 1e-12   # bigger pages, less tax
        prev = paged["total_s"]
    assert paged["pages_per_seq"] == 1
    assert paged["total_s"] == pytest.approx(dense["total_s"], rel=1e-3)


def test_chunked_prefill_stall_tradeoff():
    n, prompt, kvt = 3e9, 4096, 2 ** 12
    fused = costmodel.prefill_cost(n, prompt)
    one = costmodel.chunked_prefill_cost(n, prompt, prompt)
    assert one["n_chunks"] == 1
    assert one["total_s"] == pytest.approx(fused["total_s"], rel=1e-6)
    prev_total, prev_stall = one["total_s"], one["stall_s"]
    for chunk in (1024, 256, 64):
        c = costmodel.chunked_prefill_cost(n, prompt, chunk,
                                           kv_token_bytes=kvt)
        assert c["total_s"] >= prev_total - 1e-12     # chunking costs total…
        assert c["stall_s"] <= prev_stall + 1e-12     # …but bounds the stall
        prev_total, prev_stall = c["total_s"], c["stall_s"]
