"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.RandomState(0)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 256, 128, 128, 256),
    (512, 256, 384, 256, 128, 128),
    (64, 64, 64, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matmul_kernel(m, k, n, bm, bn, bk, dtype):
    a = jnp.array(rng.randn(m, k), dtype)
    b = jnp.array(rng.randn(k, n), dtype)
    got = ops.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul(a, b)
    tol = 2e-2 if dtype == np.float16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 256, 128, 128, 256),
    (64, 64, 64, 64, 64, 64),
])
def test_matmul_acc_kernel(m, k, n, bm, bn, bk):
    """matmul_acc(a, b, c) == c + a @ b, with the accumulator seeded from c."""
    a = jnp.array(rng.randn(m, k), np.float32)
    b = jnp.array(rng.randn(k, n), np.float32)
    c = jnp.array(rng.randn(m, n), np.float32)
    got = ops.matmul_acc(a, b, c, bm=bm, bn=bn, bk=bk, interpret=True)
    want = c + ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_matmul_acc_no_temporary():
    """The accumulate variant is one aliased pallas_call: c's buffer IS the
    output (input_output_aliases) and no separate A@B product + add appears
    in the jaxpr — the per-panel temporary of `c + matmul(a, b)` is gone."""
    x = jnp.ones((128, 128), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, b, c: ops.matmul_acc(a, b, c, interpret=True))(x, x, x)
    prims = [e.primitive.name for e in jaxpr.jaxpr.eqns]
    assert prims == ["pallas_call"], prims
    aliases = jaxpr.jaxpr.eqns[0].params["input_output_aliases"]
    assert tuple(aliases) == ((2, 0),), aliases
    # the unfused form materializes the product: pallas_call + add
    jaxpr_unfused = jax.make_jaxpr(
        lambda a, b, c: c + ops.matmul(a, b, interpret=True))(x, x, x)
    prims_unfused = [e.primitive.name for e in jaxpr_unfused.jaxpr.eqns]
    assert "add" in prims_unfused, prims_unfused


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 64, 128), (64, 256, 64)])
@pytest.mark.parametrize("uk", [4, 8])
def test_minplus_kernel(m, k, n, uk):
    a = jnp.array(rng.rand(m, k) * 10, jnp.float32)
    b = jnp.array(rng.rand(k, n) * 10, jnp.float32)
    got = ops.minplus(a, b, bm=64, bn=64, bk=64, uk=uk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.minplus(a, b)),
                               rtol=1e-6)


@pytest.mark.parametrize("B,Hq,Hkv,Lq,Lk,D,causal,window", [
    (1, 4, 2, 256, 256, 64, True, None),     # GQA causal prefill
    (2, 2, 2, 128, 128, 32, False, None),    # MHA bidirectional
    (1, 4, 1, 256, 256, 64, True, 96),       # sliding window
    (1, 2, 1, 1, 256, 64, True, None),       # decode (1 query vs cache)
    (1, 8, 8, 128, 128, 128, True, None),    # hd=128 MXU-aligned
])
def test_flash_attention_kernel(B, Hq, Hkv, Lq, Lk, D, causal, window):
    q = jnp.array(rng.randn(B, Hq, Lq, D), jnp.float32)
    k = jnp.array(rng.randn(B, Hkv, Lk, D), jnp.float32)
    v = jnp.array(rng.randn(B, Hkv, Lk, D), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True, bq=64, bkv=64)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = jnp.array(rng.randn(1, 4, 128, 64), jnp.bfloat16)
    k = jnp.array(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    v = jnp.array(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, interpret=True, bq=64, bkv=64)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)
