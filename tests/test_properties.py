"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import costmodel
from repro.kernels import ref
from repro.parallel.steps import cross_entropy
from repro.models import layers as L
from repro.config import ModelConfig

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype="float32")


# ---------------------------------------------------------------------------
# cost model (Table 1)
# ---------------------------------------------------------------------------
@given(m=st.integers(1, 10**9), p=st.sampled_from([2, 4, 16, 64, 256]))
def test_reduce_cheaper_than_allgather(m, p):
    """Θ(log p) reduce never beats Θ(p) gather asymptotically: for any size,
    reduceD ≤ allGatherD at equal message size (t_s, t_w > 0, p ≥ 2)."""
    assert costmodel.t_reduce(m, p) <= costmodel.t_all_gather(m, p) + 1e-12


@given(m=st.integers(1, 10**9), p=st.sampled_from([2, 4, 16, 64]))
def test_costs_monotone_in_p(m, p):
    for fn in (costmodel.t_reduce, costmodel.t_broadcast, costmodel.t_all_gather,
               costmodel.t_all_to_all, costmodel.t_all_reduce, costmodel.t_scan,
               costmodel.t_reduce_scatter, costmodel.t_reduce_scatter_ring):
        assert fn(m, 2 * p) >= fn(m, p) - 1e-12


@given(m=st.integers(1, 10**9), p=st.sampled_from([2, 4, 16, 64, 256]))
def test_scan_between_shift_and_allgather(m, p):
    """scanD is a log-depth pattern: dearer than one hop, cheaper than the
    Θ(p) ring gather at equal message size."""
    assert costmodel.t_shift(m, p) <= costmodel.t_scan(m, p) + 1e-12
    assert costmodel.t_scan(m, p) <= costmodel.t_all_gather(m, p) + 1e-12


@given(st.integers(64, 4096))
def test_isoefficiency_2d_between_grid_and_generic(p):
    """The 2D family sits between DNS and generic on the scalability ladder
    (§4.3 analysis extended): grid ≤ cannon ≤ {summa, generic}.  summa vs
    generic is only asymptotic (log p ≤ p^{1/6} needs astronomically large
    p), so it is not asserted at these sizes."""
    assert costmodel.isoefficiency_matmul_grid(p) <= \
        costmodel.isoefficiency_matmul_cannon(p)
    assert costmodel.isoefficiency_matmul_cannon(p) <= \
        costmodel.isoefficiency_matmul_summa(p)
    assert costmodel.isoefficiency_matmul_cannon(p) <= \
        costmodel.isoefficiency_matmul_generic(p)


@given(st.integers(2, 4096))
def test_isoefficiency_orderings(p):
    """Paper §4: grid algorithm scales better than generic (W_grid ≤ W_generic
    up to constants for large p)."""
    if p >= 64:
        assert costmodel.isoefficiency_matmul_grid(p) <= \
            costmodel.isoefficiency_matmul_generic(p)


@given(flops=st.floats(1e6, 1e18), byts=st.floats(1e3, 1e15),
       coll=st.floats(0, 1e15), chips=st.sampled_from([1, 256, 512]))
def test_roofline_dominant_is_max(flops, byts, coll, chips):
    t = costmodel.roofline_terms(flops, byts, coll, chips)
    assert t["bound_s"] == max(t["compute_s"], t["memory_s"], t["collective_s"])


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
@given(b=st.integers(1, 3), s=st.integers(2, 9), v=st.integers(2, 33),
       seed=st.integers(0, 100))
def test_cross_entropy_matches_naive(b, s, v, seed):
    rng = np.random.RandomState(seed)
    logits = jnp.array(rng.randn(b, s, v), jnp.float32)
    labels = jnp.array(rng.randint(0, v, (b, s)))
    got = float(cross_entropy(logits, labels))
    lp = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    want = -np.mean([lp[i, j, labels[i, j]] for i in range(b) for j in range(s)])
    np.testing.assert_allclose(got, want, rtol=1e-4)


@given(s=st.sampled_from([8, 16]), chunk=st.sampled_from([2, 4, 8]))
def test_cross_entropy_chunked_equal(s, chunk):
    rng = np.random.RandomState(0)
    logits = jnp.array(rng.randn(2, s, 16), jnp.float32)
    labels = jnp.array(rng.randint(0, 16, (2, s)))
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               float(cross_entropy(logits, labels, chunk=chunk)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 50))
def test_rope_preserves_norm(seed):
    rng = np.random.RandomState(seed)
    x = jnp.array(rng.randn(1, 8, 2, 32), jnp.float32)
    pos = jnp.arange(8)
    y = L.rope(x, pos, CFG)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)


@given(seed=st.integers(0, 20), i=st.integers(0, 6))
def test_attention_causality(seed, i):
    """Output at position i must not depend on tokens at positions > i."""
    rng = np.random.RandomState(seed)
    p = L.attention_init(jax.random.PRNGKey(seed), CFG)
    x1 = jnp.array(rng.randn(1, 8, 32), jnp.float32)
    x2 = np.asarray(x1).copy()
    x2[:, i + 1:] += rng.randn(*x2[:, i + 1:].shape)  # perturb the future
    pos = jnp.arange(8)
    y1, _ = L.attention(p, x1, pos, CFG)
    y2, _ = L.attention(p, jnp.array(x2), pos, CFG)
    np.testing.assert_allclose(np.asarray(y1)[:, :i + 1],
                               np.asarray(y2)[:, :i + 1], rtol=1e-3, atol=1e-4)


@given(seed=st.integers(0, 20))
def test_minplus_semiring_identity(seed):
    """A ⊗ I_minplus == A where I has 0 diagonal, +inf elsewhere."""
    rng = np.random.RandomState(seed)
    a = jnp.array(rng.rand(16, 16) * 5, jnp.float32)
    eye = jnp.where(jnp.eye(16, dtype=bool), 0.0, jnp.inf).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(ref.minplus(a, eye)), np.asarray(a),
                               rtol=1e-6)


@given(seed=st.integers(0, 20))
def test_flash_ref_matches_softmax_attention(seed):
    """The flash oracle equals dense softmax attention (no masking)."""
    rng = np.random.RandomState(seed)
    q = jnp.array(rng.randn(1, 2, 8, 16), jnp.float32)
    k = jnp.array(rng.randn(1, 2, 8, 16), jnp.float32)
    v = jnp.array(rng.randn(1, 2, 8, 16), jnp.float32)
    got = ref.flash_attention(q, k, v, causal=False)
    s = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(16)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bhkd->bhqd", p, v)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ssm engine
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 10), chunk=st.sampled_from([2, 4, 16]))
def test_chunked_engine_chunk_invariance(seed, chunk):
    """The chunked linear recurrence gives the same answer for any chunk size
    (and matches the naive sequential recurrence)."""
    from repro.models.ssm import chunked_linear_attention
    rng = np.random.RandomState(seed)
    b, s, h, dk, dv = 1, 16, 2, 4, 4
    q = jnp.array(rng.randn(b, s, h, dk), jnp.float32)
    k = jnp.array(rng.randn(b, s, h, dk), jnp.float32)
    v = jnp.array(rng.randn(b, s, h, dv), jnp.float32)
    la = jnp.array(-np.abs(rng.rand(b, s, h)) * 0.1, jnp.float32)
    g = jnp.array(rng.rand(b, s, h), jnp.float32)

    y, _ = chunked_linear_attention(q, k, v, la, g, chunk=chunk)

    # naive recurrence
    state = np.zeros((b, h, dk, dv))
    want = np.zeros((b, s, h, dv))
    qn, kn, vn = map(np.asarray, (q, k, v))
    for t in range(s):
        state = state * np.exp(np.asarray(la)[:, t])[..., None, None] + \
            np.einsum("bh,bhd,bhv->bhdv", np.asarray(g)[:, t], kn[:, t], vn[:, t])
        want[:, t] = np.einsum("bhd,bhdv->bhv", qn[:, t], state)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-4)
