"""DSeq algebra + paper algorithms + MoE EP, via multi-device subprocesses
(the main test process must keep the default 1-device CPU config)."""
import os
import subprocess
import sys

import pytest

PROGS = os.path.join(os.path.dirname(__file__), "progs")


def _run(prog: str, marker: str):
    r = subprocess.run([sys.executable, os.path.join(PROGS, prog)],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"{prog} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    assert marker in r.stdout


def test_dseq_table1_operations():
    """Every Table-1 op (mapD/zipWithD implicit, reduceD sum/tree/min with and
    without root, shiftD, allGatherD, allToAllD, apply, scanD) on an 8-process
    group + a non-power-of-two group."""
    _run("dseq_prog.py", "DSEQ_OK")


def test_group_collectives_properties():
    """scanD ≡ cumsum (incl/excl/max), reduceScatterD ≡ reshaped psum,
    ringShiftD/allGatherRingD oracles — property-tested on 4- and 8-process
    groups (hypothesis when installed, seeded sweep otherwise)."""
    _run("collectives_prog.py", "COLLECTIVES_OK")


def test_summa_cannon_matmul():
    """SUMMA + Cannon ≡ jnp.matmul on 2×2 and 2×4 grids (square, rectangular
    operands, Pallas local multiply) + Cannon-vs-SUMMA cost-model tie."""
    _run("summa_prog.py", "SUMMA_OK")


@pytest.mark.slow
def test_paper_algorithms():
    """DNS matmul (Grid3D + Pallas local multiply), generic Algorithm 1,
    Floyd-Warshall (faithful + blocked), FooPar TP matmuls inside pjit."""
    _run("paper_algos_prog.py", "ALGOS_OK")


@pytest.mark.slow
def test_moe_expert_parallel():
    """EP and TP expert layouts match the single-device oracle; grads flow."""
    _run("moe_ep_prog.py", "MOE_OK")


def test_foopar_tp_mlp():
    """Algebra-based TP MLP (paper-faithful path) matches the pjit MLP and
    differentiates (jitted)."""
    _run("foopar_tp_prog.py", "FOOPAR_TP_OK")


def test_manual_attention():
    """Manual shard_map SDPA (§Perf A8) matches the reference attention."""
    _run("manual_attn_prog.py", "MANUAL_ATTN_OK")
