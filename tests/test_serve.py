"""Serving-path tests: fused prefill oracles (cache-writing full-sequence
forward ≡ per-token decode loop) and the continuous-batching scheduler."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ParallelConfig
from repro.launch.scheduler import Request, Scheduler, make_requests
from repro.launch.train import reduced
from repro.models import encdec as E
from repro.models import transformer as T


def tiny(arch, **kw):
    """Reduced config in f32 (prefill and decode must agree numerically)."""
    return reduced(configs.get(arch)).replace(
        dtype="float32", param_dtype="float32", vocab=64, **kw)


def _step(cfg):
    """Jitted decode step (one trace per config instead of an eager retrace
    of the layer scan per token — keeps the tier-1 budget)."""
    return jax.jit(lambda p, t, c, i: T.decode_step(p, t, c, i, cfg))


def _prefill(cfg):
    return jax.jit(lambda p, t, c, ln=None: T.prefill(p, t, c, cfg, length=ln))


def decode_loop(cfg, params, prompts, max_len, *, step=None):
    """Token-by-token reference: returns (last logits, cache) after feeding
    every prompt token through the decode step."""
    step = step or _step(cfg)
    cache = T.init_cache(cfg, prompts.shape[0], max_len, dtype=jnp.float32)
    logit = None
    for i in range(prompts.shape[1]):
        logit, cache = step(params, prompts[:, i], cache, jnp.int32(i))
    return logit, cache


# ---------------------------------------------------------------------------
# Fused prefill oracle: one cache-writing forward ≡ the decode loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,pattern", [
    ("llama3.2-3b", None),                        # dense GQA
    ("chatglm3-6b", None),                        # sliding-window (reduced: 64)
    ("zamba2-1.2b", ("mamba2", "mamba2_attn")),   # recurrent + shared attn
    ("xlstm-1.3b", ("mlstm", "slstm")),           # chunked mLSTM + sLSTM
])
def test_fused_prefill_matches_decode_loop(arch, pattern):
    cfg = tiny(arch)
    if pattern:
        cfg = cfg.replace(block_pattern=pattern, n_layers=len(pattern))
    params = T.init(jax.random.PRNGKey(0), cfg)
    b, lp, max_len = 2, 4, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, lp), 0, cfg.vocab)

    step = _step(cfg)
    ref_logit, ref_cache = decode_loop(cfg, params, prompts, max_len, step=step)
    logit, cache = _prefill(cfg)(
        params, prompts, T.init_cache(cfg, b, max_len, dtype=jnp.float32))
    np.testing.assert_allclose(logit, ref_logit, atol=1e-4, rtol=1e-4)

    # one more decode step from both caches must also agree (the cache state,
    # not just the logits, is equivalent)
    tok = jnp.argmax(logit, axis=-1).astype(jnp.int32)
    nxt_f, _ = step(params, tok, cache, jnp.int32(lp))
    nxt_r, _ = step(params, tok, ref_cache, jnp.int32(lp))
    np.testing.assert_allclose(nxt_f, nxt_r, atol=1e-4, rtol=1e-4)


def test_fused_prefill_right_padded_lengths():
    """Per-row true lengths on a right-padded batch: each row's last logits
    equal its own unpadded run (pad tokens are causally invisible)."""
    cfg = tiny("llama3.2-3b")
    params = T.init(jax.random.PRNGKey(0), cfg)
    lens, lb, max_len = [5, 3], 8, 12
    rng = np.random.RandomState(2)
    toks = np.zeros((2, lb), np.int32)
    rows = [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]
    for r, row in enumerate(rows):
        toks[r, :len(row)] = row

    logit, _ = _prefill(cfg)(params, jnp.asarray(toks),
                             T.init_cache(cfg, 2, max_len, dtype=jnp.float32),
                             jnp.asarray(lens, jnp.int32))
    step = _step(cfg)
    for r, row in enumerate(rows):
        ref, _ = decode_loop(cfg, params, jnp.asarray(row)[None], max_len,
                             step=step)
        np.testing.assert_allclose(logit[r], ref[0], atol=1e-4, rtol=1e-4)


def test_fused_prefill_prompt_longer_than_window():
    """SWA ring: a prompt longer than the window prefills the trailing ring
    slots, and the next ring decode step matches the per-token loop (which
    also exercises the pre-wrap slot-validity mask)."""
    cfg = tiny("llama3.2-3b").replace(window=4)
    params = T.init(jax.random.PRNGKey(0), cfg)
    b, lp, max_len = 2, 8, 16     # cache ring length = window = 4 < lp
    prompts = jax.random.randint(jax.random.PRNGKey(3), (b, lp), 0, cfg.vocab)

    step = _step(cfg)
    ref_logit, ref_cache = decode_loop(cfg, params, prompts, max_len, step=step)
    logit, cache = _prefill(cfg)(
        params, prompts, T.init_cache(cfg, b, max_len, dtype=jnp.float32))
    np.testing.assert_allclose(logit, ref_logit, atol=1e-4, rtol=1e-4)
    tok = jnp.argmax(logit, axis=-1).astype(jnp.int32)
    nxt_f, _ = step(params, tok, cache, jnp.int32(lp))
    nxt_r, _ = step(params, tok, ref_cache, jnp.int32(lp))
    np.testing.assert_allclose(nxt_f, nxt_r, atol=1e-4, rtol=1e-4)


def test_padded_prefill_rejects_bucket_beyond_ring():
    """A right-padded bucket longer than the SWA ring would keep pad K/V in
    the cache (the trailing-window write can't see per-row lengths)."""
    cfg = tiny("llama3.2-3b").replace(window=4)
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(NotImplementedError, match="cache ring"):
        T.prefill(params, toks, T.init_cache(cfg, 1, 16), cfg,
                  length=jnp.asarray([3], jnp.int32))


def test_encdec_fused_prefill_matches_decode_loop():
    cfg = tiny("whisper-base")
    params = E.init(jax.random.PRNGKey(0), cfg)
    b, lp, t_enc, max_len = 2, 4, 6, 8
    frames = jax.random.normal(jax.random.PRNGKey(4), (b, t_enc, cfg.d_model))
    prompts = jax.random.randint(jax.random.PRNGKey(5), (b, lp), 0, cfg.vocab)
    enc = E.encode(params, frames, cfg)
    step = jax.jit(lambda p, t, c, i, e: E.decode_step(p, t, c, i, e, cfg))

    ref_cache = E.init_cache(cfg, b, max_len, dtype=jnp.float32)
    ref_logit = None
    for i in range(lp):
        ref_logit, ref_cache = step(params, prompts[:, i], ref_cache,
                                    jnp.int32(i), enc)
    logit, cache = E.decode_prefill(params, prompts, enc,
                                    E.init_cache(cfg, b, max_len,
                                                 dtype=jnp.float32), cfg)
    np.testing.assert_allclose(logit, ref_logit, atol=1e-4, rtol=1e-4)
    tok = jnp.argmax(logit, axis=-1).astype(jnp.int32)
    nxt_f, _ = step(params, tok, cache, jnp.int32(lp), enc)
    nxt_r, _ = step(params, tok, ref_cache, jnp.int32(lp), enc)
    np.testing.assert_allclose(nxt_f, nxt_r, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Continuous-batching scheduler
# ---------------------------------------------------------------------------
def test_scheduler_staggered_arrivals_complete_and_order_independent():
    """Heterogeneous staggered requests all complete through a 2-slot pool,
    and each request's greedy tokens are identical to serving it alone —
    outputs must not depend on what shares the batch."""
    cfg = tiny("llama3.2-3b")
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(7)
    spec = [(4, 3, 0), (2, 5, 0), (6, 2, 1), (1, 4, 3), (0, 3, 3)]
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab, (lp,)).astype(np.int32),
                    gen=gen, arrival=arr)
            for i, (lp, gen, arr) in enumerate(spec)]

    sched = Scheduler(cfg, pcfg, params, slots=2, max_len=16, bucket=8)
    out = sched.run(reqs)
    comps = out["completions"]
    assert sorted(comps) == [0, 1, 2, 3, 4]
    assert out["generated"] == sum(g for _, g, _ in spec)
    for i, (lp, gen, arr) in enumerate(spec):
        assert len(comps[i].tokens) == gen
        assert comps[i].admitted_tick >= arr

    solo = Scheduler(cfg, pcfg, params, slots=1, max_len=16, bucket=8)
    for req in reqs:
        alone = solo.run([Request(rid=req.rid, prompt=req.prompt,
                                  gen=req.gen, arrival=0)])
        assert alone["completions"][req.rid].tokens == comps[req.rid].tokens, \
            f"request {req.rid} depends on batching context"
        solo.reset()


def test_scheduler_empty_prompt_reuses_slot_with_fresh_state():
    """A recurrent-family slot must be zeroed when an empty-prompt request
    reuses it: state leaves have no position indexing, so the previous
    occupant's SSM state is not causally masked away like stale KV."""
    cfg = tiny("xlstm-1.3b").replace(block_pattern=("mlstm", "slstm"),
                                     n_layers=2)
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(11)
    warm = Request(rid=0, prompt=rng.randint(0, cfg.vocab, (4,)).astype(np.int32),
                   gen=2, arrival=0)
    empty = Request(rid=1, prompt=np.zeros((0,), np.int32), gen=3, arrival=0)

    sched = Scheduler(cfg, pcfg, params, slots=1, max_len=16)
    reused = sched.run([warm, empty])["completions"][1].tokens
    sched.reset()
    alone = sched.run([empty])["completions"][1].tokens
    assert reused == alone


def test_scheduler_sampling_reproducible_and_tempered():
    """Temperature/top-p sampling in the slot loop: a fixed seed reproduces
    the token stream exactly (fresh engine or after reset), a different
    seed diverges, and a vanishing top-p collapses to the greedy oracle."""
    from repro.launch.scheduler import sample_tokens
    cfg = tiny("llama3.2-3b")
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    reqs = lambda: make_requests(3, 4, 5, cfg.vocab, stagger=1)
    toks = lambda out: {r: c.tokens for r, c in out["completions"].items()}

    greedy = toks(Scheduler(cfg, pcfg, params, slots=2, max_len=16).run(reqs()))

    s = Scheduler(cfg, pcfg, params, slots=2, max_len=16,
                  temperature=0.8, top_p=0.9, seed=3)
    a = toks(s.run(reqs()))
    s.reset()
    assert toks(s.run(reqs())) == a          # reset restarts the stream
    b = toks(Scheduler(cfg, pcfg, params, slots=2, max_len=16,
                       temperature=0.8, top_p=0.9, seed=3).run(reqs()))
    assert a == b                            # same seed, fresh engine
    c = toks(Scheduler(cfg, pcfg, params, slots=2, max_len=16,
                       temperature=0.8, top_p=0.9, seed=4).run(reqs()))
    assert c != a                            # different stream
    for t in a.values():
        assert all(0 <= tok < cfg.vocab for tok in t)

    # top-p → 0 keeps only the argmax token: greedy, token for token
    g = toks(Scheduler(cfg, pcfg, params, slots=2, max_len=16,
                       temperature=1.0, top_p=1e-9, seed=5).run(reqs()))
    assert g == greedy

    # sampling config validation
    with pytest.raises(ValueError):
        Scheduler(cfg, pcfg, params, slots=1, max_len=16, temperature=-0.1)
    with pytest.raises(ValueError):
        Scheduler(cfg, pcfg, params, slots=1, max_len=16, top_p=0.0)

    # unit: nucleus mask keeps exactly the smallest prefix of mass >= top_p
    logits = jnp.log(jnp.array([[0.5, 0.3, 0.15, 0.05]], jnp.float32))
    for i in range(20):
        tok = int(sample_tokens(logits, jax.random.PRNGKey(i), 1.0, 0.6)[0])
        assert tok in (0, 1), tok
    assert int(sample_tokens(logits, jax.random.PRNGKey(0), 0.0)[0]) == 0


def test_scheduler_sampling_recurrent_prefill_path():
    """The per-token (non-fused) prefill fallback samples its first token
    from the last prompt logits — seeded reproducibility holds there too."""
    cfg = tiny("xlstm-1.3b").replace(block_pattern=("mlstm",), n_layers=1)
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    reqs = lambda: make_requests(2, 3, 3, cfg.vocab)
    s1 = Scheduler(cfg, pcfg, params, slots=1, max_len=8,
                   temperature=0.7, seed=9)
    assert not s1.fused
    a = {r: c.tokens for r, c in s1.run(reqs())["completions"].items()}
    s2 = Scheduler(cfg, pcfg, params, slots=1, max_len=8,
                   temperature=0.7, seed=9)
    assert {r: c.tokens for r, c in s2.run(reqs())["completions"].items()} == a


def test_scheduler_streams_and_validates():
    cfg = tiny("llama3.2-3b")
    pcfg = ParallelConfig(remat="none", fsdp_params=False)
    params = T.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        Scheduler(cfg, pcfg, params, slots=0, max_len=16)
    sched = Scheduler(cfg, pcfg, params, slots=2, max_len=8)
    with pytest.raises(ValueError):   # prompt + gen must fit a slot
        sched.run([Request(rid=0, prompt=np.zeros(6, np.int32), gen=5)])
    sched.reset()
    seen = []
    out = sched.run(make_requests(2, 3, 4, cfg.vocab),
                    on_token=lambda rid, tok: seen.append((rid, tok)))
    assert len(seen) == out["generated"] == 8
    assert out["tok_s"] > 0 and out["wall_s"] > 0


# ---------------------------------------------------------------------------
# serve CLI
# ---------------------------------------------------------------------------
def test_serve_cli_rejects_bad_args(monkeypatch):
    from repro.launch import serve
    for bad in (["--requests", "0"], ["--gen", "0"], ["--slots", "0"],
                ["--prompt-len", "-1"], ["--prompt-len", "0", "--gen", "1"],
                ["--temperature", "-0.5"], ["--top-p", "0"],
                ["--top-p", "1.5"]):
        monkeypatch.setattr(sys, "argv", ["serve"] + bad)
        with pytest.raises(SystemExit) as e:
            serve.main()
        assert e.value.code == 2      # argparse usage error


@pytest.mark.slow
def test_serve_cli_runs_including_empty_prompt():
    """The launcher end-to-end, including --prompt-len 0 (used to NameError
    on the unbound first token), the --naive A/B flag, and the paged engine
    (whose report must include the pool occupancy/fragmentation line)."""
    import os
    env = {**os.environ, "PYTHONPATH": "src"}
    cwd = os.path.join(os.path.dirname(__file__), "..")
    for extra in (["--prompt-len", "0"], ["--naive"],
                  ["--paged", "--block", "4", "--chunk", "4"]):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "llama3.2-3b", "--requests", "2", "--prompt-len", "4", "--gen",
             "3", "--slots", "2"] + extra,
            capture_output=True, text=True, timeout=600, env=env, cwd=cwd)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        assert "served 2 requests" in r.stdout
        if "--paged" in extra:
            assert "peak occupancy" in r.stdout
