"""Planner units: deterministic, monotone plan ranking; scatter-spec /
sanitize-spec layout rules; ZeRO-vs-allreduce trajectory oracle (subprocess:
needs the 8-device CPU mesh)."""
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.config import ParallelConfig
from repro.core import costmodel
from repro.core.compat import abstract_mesh
from repro.models import transformer as T
from repro.models.moe import MeshCtx
from repro.parallel import planner
from repro.parallel.sharding import (dropped_partition_report, opt_specs,
                                     param_specs, reset_dropped_partitions,
                                     sanitize_spec, scatter_specs)

PROGS = os.path.join(os.path.dirname(__file__), "progs")
ARCH = "llama3.2-3b"


# ---------------------------------------------------------------------------
# plan_search ranking
# ---------------------------------------------------------------------------
def test_plan_search_deterministic():
    cfg = configs.get(ARCH)
    a = planner.plan_search(cfg, (16, 16), 256, 4096, "train")
    b = planner.plan_search(cfg, (16, 16), 256, 4096, "train")
    assert [r.plan.label() for r in a] == [r.plan.label() for r in b]
    assert [r.total_s for r in a] == [r.total_s for r in b]
    assert a and a[0].feasible, "no feasible plan for the 3B cell"


def test_plan_search_more_hbm_superset():
    """More HBM per chip ⇒ the feasible set only grows (monotone gate)."""
    cfg = configs.get(ARCH)
    small = {r.plan.label() for r in
             planner.plan_search(cfg, (16, 16), 256, 4096, "train",
                                 hbm=8 * 2**30) if r.feasible}
    big = {r.plan.label() for r in
           planner.plan_search(cfg, (16, 16), 256, 4096, "train",
                               hbm=64 * 2**30) if r.feasible}
    assert small <= big
    assert len(big) > len(small)


@pytest.mark.parametrize("p", [4, 16, 64])
def test_zero_beats_allreduce_on_larger_meshes(p):
    """On a pure-DP mesh the zero strategy's predicted comm + optimizer
    traffic undercuts the all-reduce step's for every dp ≥ 4 (the f32 grad
    reduce-scatter moves half the wire bytes of the all-reduce, and the
    redundant full update disappears) — and the gap widens with the mesh."""
    cfg = configs.get(ARCH)
    pc = cfg.param_counts()

    def cost(grad):
        return costmodel.train_step_cost(
            pc["active"], pc["total"], tokens=4096.0 * p, chips=p, tp=1,
            dp=p, fsdp_shard=1, grad=grad, batch_local=1, seq=4096,
            d_model=cfg.d_model, n_layers=cfg.n_layers, grad_bytes=4)

    ar, z = cost("all_reduce"), cost("reduce_scatter_zero")
    assert z["grad_s"] < ar["grad_s"]
    assert z["update_s"] < ar["update_s"]
    assert z["total_s"] < ar["total_s"]
    # the advantage is monotone in the mesh: at 2p the ratio doesn't shrink
    ar2 = costmodel.train_step_cost(
        pc["active"], pc["total"], tokens=4096.0 * 2 * p, chips=2 * p, tp=1,
        dp=2 * p, fsdp_shard=1, grad="all_reduce", batch_local=1, seq=4096,
        d_model=cfg.d_model, n_layers=cfg.n_layers, grad_bytes=4)
    z2 = costmodel.train_step_cost(
        pc["active"], pc["total"], tokens=4096.0 * 2 * p, chips=2 * p, tp=1,
        dp=2 * p, fsdp_shard=1, grad="reduce_scatter_zero", batch_local=1,
        seq=4096, d_model=cfg.d_model, n_layers=cfg.n_layers, grad_bytes=4)
    assert (ar2["update_s"] - z2["update_s"]) >= \
        (ar["update_s"] - z["update_s"]) * 0.99


def test_zero_memory_scales_down_with_dp():
    """ZeRO shards grads + moments over dp: per-device state bytes drop as
    1/dp (ZeRO's Θ(2m/p) vs Θ(2m)); the all-reduce layout stays flat."""
    n = 1e9
    prev = None
    for dp in (2, 4, 8, 16):
        z = costmodel.train_memory_bytes(n, dp=dp, grad="reduce_scatter_zero")
        ar = costmodel.train_memory_bytes(n, dp=dp, grad="all_reduce")
        assert z["opt"] * dp == pytest.approx(ar["opt"])
        assert z["grads"] * dp == pytest.approx(ar["grads"])
        if prev is not None:
            assert z["total"] < prev
        prev = z["total"]


def test_default_plan_properties():
    """The production train cell picks a memory-feasible ZeRO point with
    full remat and f32 moments (the numerics guard), and the serve cell
    reproduces the TP-resident-when-it-fits rule."""
    plan = planner.default_plan(ARCH, "train")
    assert plan.grad == "reduce_scatter_zero"
    assert plan.remat == "full"
    assert plan.opt_state_dtype == "float32"
    pcfg = plan.to_pcfg()
    assert pcfg.grad_reduce == "reduce_scatter_zero"
    # 3B params at bf16 fit one chip's TP shard comfortably: no FSDP gathers
    assert planner.default_plan(ARCH, "decode").fsdp_axes == ()
    # 405B does not: params stay FSDP-sharded for serving
    assert planner.default_plan("llama3-405b", "decode").fsdp_axes


def test_plan_lattice_head_is_runnable_when_nothing_fits():
    """Even when no point fits (405B train on 16 GiB chips at this batch),
    plan_search returns the full lattice ranked with the least-infeasible
    point first — never an empty list."""
    cfg = configs.get("llama3-405b")
    ranked = planner.plan_search(cfg, (16, 16), 256, 4096, "train")
    assert ranked
    mems = [r.memory["total"] for r in ranked if not r.feasible]
    if not ranked[0].feasible:
        assert ranked[0].memory["total"] == min(mems)


# ---------------------------------------------------------------------------
# layout rules
# ---------------------------------------------------------------------------
def _ctx8():
    mesh = abstract_mesh((8, 1), ("data", "model"))
    return MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                   fsdp_axes=())


def test_scatter_specs_adds_data_axis():
    from repro.launch.train import reduced
    rcfg = reduced(configs.get(ARCH))
    params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), rcfg))
    ctx = _ctx8()
    sspec = scatter_specs(params, rcfg, ctx)
    pspec = param_specs(params, rcfg, ctx)
    flat_s = jax.tree.leaves(sspec, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree.leaves(pspec, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree.leaves(params)
    changed = 0
    for s, p_, leaf in zip(flat_s, flat_p, flat_l):
        if s != p_:
            changed += 1
            # the added partition divides its dim by the scatter group
            parts = tuple(s) + (None,) * (leaf.ndim - len(tuple(s)))
            hit = [i for i, a in enumerate(parts) if a == "data"
                   or (isinstance(a, tuple) and "data" in a)]
            assert hit and leaf.shape[hit[0]] % 8 == 0, (s, leaf.shape)
    assert changed > 0


def test_scatter_specs_noop_on_fsdp_sharded_leaves():
    """FSDP param storage already scatters the matrix leaves — the ZeRO
    layout must not double-shard those; only the FSDP-replicated stragglers
    (norm scales, biases) gain a scatter axis."""
    from repro.launch.train import reduced
    rcfg = reduced(configs.get(ARCH))
    params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), rcfg))
    mesh = abstract_mesh((8, 1), ("data", "model"))
    ctx = MeshCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                  fsdp_axes=("data",))
    sspec = scatter_specs(params, rcfg, ctx)
    pspec = param_specs(params, rcfg, ctx)
    is_p = lambda x: isinstance(x, P)
    for s, p_ in zip(jax.tree.leaves(sspec, is_leaf=is_p),
                     jax.tree.leaves(pspec, is_leaf=is_p)):
        had_data = any(a == "data" or (isinstance(a, tuple) and "data" in a)
                       for a in tuple(p_))
        if had_data:
            assert s == p_, (s, p_)


def test_opt_specs_scatter_layout():
    pspec = {"w": P(None, "model")}
    sspec = {"w": P("data", "model")}
    assert opt_specs(pspec)["m"] is pspec
    o = opt_specs(pspec, sspec)
    assert o["m"] is sspec and o["v"] is sspec and o["step"] == P()


def test_sanitize_spec_reports_dropped_partitions():
    mesh = abstract_mesh((8, 1), ("data", "model"))
    reset_dropped_partitions()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        kept = sanitize_spec(P("data"), (64,), mesh, path="ok/leaf")
        dropped = sanitize_spec(P("data"), (7,), mesh, path="bad/leaf")
    assert kept == P("data") and dropped == P(None)
    rep = dropped_partition_report()
    assert [r["leaf"] for r in rep] == ["bad/leaf"]
    assert rep[0]["axes"] == ("data",) and rep[0]["shard"] == 8
    reset_dropped_partitions()
    assert dropped_partition_report() == []


# ---------------------------------------------------------------------------
# trajectory oracle (8-device subprocess)
# ---------------------------------------------------------------------------
def test_zero_step_matches_allreduce_trajectory():
    """make_train_step_zero ≡ make_train_step on a 1×8 CPU mesh: loss
    trajectory bit-for-bit in f32, params to layout-ulps, moments stored as
    1/8 shards."""
    r = subprocess.run([sys.executable, os.path.join(PROGS, "zero_step_prog.py")],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    assert "ZERO_OK" in r.stdout
