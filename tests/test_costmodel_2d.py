"""Unit tests for the scan / reduce-scatter / 2D-matmul cost-model additions.

Plain pytest (no hypothesis dependency) so these always run; the
hypothesis-widened versions live in test_properties.py.
"""
import math

import pytest

from repro.core import costmodel as cm

PS = [2, 4, 8, 16, 64, 256]


@pytest.mark.parametrize("p", PS[:-1])
def test_t_scan_monotone_in_p(p):
    for m in (1, 1024, 10**9):
        assert cm.t_scan(m, 2 * p) >= cm.t_scan(m, p) - 1e-15


@pytest.mark.parametrize("p", PS[:-1])
def test_t_reduce_scatter_monotone_in_p(p):
    for m in (1, 1024, 10**9):
        assert cm.t_reduce_scatter(m, 2 * p) >= cm.t_reduce_scatter(m, p) - 1e-15
        assert cm.t_reduce_scatter_ring(m, 2 * p) >= \
            cm.t_reduce_scatter_ring(m, p) - 1e-15


@pytest.mark.parametrize("p", PS[:-1])
def test_isoefficiency_summa_monotone_in_p(p):
    assert cm.isoefficiency_matmul_summa(2 * p) > cm.isoefficiency_matmul_summa(p)
    assert cm.isoefficiency_matmul_cannon(2 * p) > cm.isoefficiency_matmul_cannon(p)


@pytest.mark.parametrize("p", [64, 256, 1024, 4096])
def test_isoefficiency_2d_orderings(p):
    """Scalability ladder at scale: DNS (Θ(p log p)) ≤ Cannon (Θ(p^1.5)) ≤
    SUMMA (Θ(p^1.5 log p)), and Cannon ≤ generic (Θ(p^5/3)).  SUMMA vs
    generic flips only at astronomically large p (log p vs p^{1/6}), so it
    is not asserted here."""
    assert cm.isoefficiency_matmul_grid(p) <= cm.isoefficiency_matmul_cannon(p)
    assert cm.isoefficiency_matmul_cannon(p) <= cm.isoefficiency_matmul_summa(p)
    assert cm.isoefficiency_matmul_cannon(p) <= cm.isoefficiency_matmul_generic(p)


def test_scan_cost_shape():
    """t_scan is the reduce cost with the per-round combine included, and is
    latency-exact for powers of two: ceil(log2 p) rounds."""
    assert cm.t_scan(0, 8, cm.ICI) == 3 * cm.ICI.t_s
    assert cm.t_scan(100, 1) == 0.0
    assert cm.t_scan(100, 8, t_lambda=1e-6) > cm.t_scan(100, 8)


def test_reduce_scatter_vs_all_reduce():
    """reduce-scatter is the cheap half of an all-reduce: ≤ t_all_reduce for
    every size/grid."""
    for p in PS:
        for m in (64, 2**20, 10**9):
            assert cm.t_reduce_scatter(m, p) <= cm.t_all_reduce(m, p) + 1e-15


@pytest.mark.parametrize("n,q", [(1024, 2), (4096, 4), (40000, 8)])
def test_summa_cannon_cost_structure(n, q):
    s = cm.summa_matmul_cost(n, q)
    c = cm.cannon_matmul_cost(n, q)
    d = cm.dns_matmul_cost(n, q)
    # all variants do the same useful work and report coherent totals
    assert s["compute_s"] == pytest.approx(c["compute_s"])
    assert s["total_s"] >= s["compute_s"] and c["total_s"] >= c["compute_s"]
    assert s["serial_s"] == pytest.approx(c["serial_s"]) == pytest.approx(d["serial_s"])
    # Cannon's nearest-neighbour traffic never exceeds SUMMA's broadcasts
    assert c["shift_s"] <= s["broadcast_s"] * (1 + 1e-9)
    # 2D memory: no replication — q² processes hold 3n² elements total
    assert s["mem_elts_per_proc"] * q * q == 3 * n * n


@pytest.mark.parametrize("n,qx,qy", [(256, 2, 4), (1024, 2, 2), (1024, 2, 4),
                                     (1024, 1, 8), (4096, 2, 8), (8192, 4, 8)])
def test_summa_pipelined_leq_plain(n, qx, qy):
    """Overlap pipelining never loses where it is meant to run: the ring
    transfers replace log-tree broadcasts and hide behind compute, so
    pipelined total ≤ plain SUMMA total (same flops, same memory class).
    (On large square comm-bound grids the tree's log q beats a q-hop serial
    ring — there the chooser keeps plain SUMMA or Cannon.)"""
    s = cm.summa_matmul_cost(n, qx, qy)
    p = cm.summa_pipelined_cost(n, qx, qy)
    assert p["compute_s"] == pytest.approx(s["compute_s"])
    assert p["total_s"] <= s["total_s"] * (1 + 1e-9), (p, s)
    # the overlap term is exactly what max() saved over the serial sum
    assert p["overlap_s"] == pytest.approx(
        p["comm_s"] + p["compute_s"] - max(p["comm_s"], p["compute_s"]))


@pytest.mark.parametrize("n,q,c", [(8192, 16, 4), (8192, 32, 4), (4096, 16, 4)])
def test_cannon_25d_between_cannon_and_dns(n, q, c):
    """2.5D interpolates the memory/communication tradeoff: with p = q²c
    chips, per-process memory sits strictly between Cannon's Θ(n²/p) and
    DNS's Θ(n²/p^{2/3}) (for 1 < c < p^{1/3}), and the c-fold replication
    buys strictly less communication than Cannon on the same chip count."""
    d25 = cm.cannon_25d_cost(n, q, c)
    p = d25["p"]
    q2 = round(p ** 0.5)
    assert q2 * q2 == p, "test params must give a square 2D grid"
    ca = cm.cannon_matmul_cost(n, q2)
    q3 = round(p ** (1 / 3))
    dns_mem = 3 * (n // q3) ** 2 if q3**3 == p else None
    assert ca["mem_elts_per_proc"] < d25["mem_elts_per_proc"]
    assert d25["mem_elts_per_proc"] == 3 * c * n * n // p
    if dns_mem is not None and c < q3:
        assert d25["mem_elts_per_proc"] < dns_mem
    assert d25["comm_s"] < ca["shift_s"], (d25, ca)
    # same useful work on the same chip count
    assert d25["compute_s"] == pytest.approx(ca["compute_s"])


def test_cannon_25d_tradeoff_monotone_in_c():
    """More replication -> more memory, less communication (up to the
    reduce-dominated c = q corner, which is excluded)."""
    n, q = 8192, 32
    cs = [1, 2, 4, 8]
    costs = [cm.cannon_25d_cost(n, q, c) for c in cs]
    for lo, hi in zip(costs, costs[1:]):
        assert hi["comm_s"] < lo["comm_s"]
        assert hi["mem_elts_per_proc"] == lo["mem_elts_per_proc"]  # fixed q
    # at fixed p, memory grows with c: 3·c·n²/p
    assert cm.cannon_25d_cost(n, 16, 4)["mem_elts_per_proc"] > \
        cm.cannon_matmul_cost(n, 32)["mem_elts_per_proc"]


def test_cannon_25d_c1_matches_cannon():
    """c = 1 is plain square Cannon: no replication broadcast, no reduce,
    identical skew + ring-shift communication structure."""
    n, q = 4096, 8
    d = cm.cannon_25d_cost(n, q, 1)
    ca = cm.cannon_matmul_cost(n, q)
    assert d["replicate_s"] == 0.0 and d["reduce_s"] == 0.0
    assert d["comm_s"] == pytest.approx(ca["shift_s"])
    assert d["total_s"] == pytest.approx(ca["total_s"])


@pytest.mark.parametrize("p", [64, 512, 4096])
def test_isoefficiency_25d_interpolates(p):
    """W(p, c) = (p/c)^{3/2}: c = 1 recovers Cannon; growing c walks down
    toward the replication-bought DNS end of the scalability curve."""
    assert cm.isoefficiency_matmul_25d(p, 1) == \
        pytest.approx(cm.isoefficiency_matmul_cannon(p))
    c_max = round(p ** (1 / 3))
    prev = cm.isoefficiency_matmul_25d(p, 1)
    for c in (2, 4):
        if c > c_max:
            break
        cur = cm.isoefficiency_matmul_25d(p, c)
        assert cur < prev
        prev = cur
    # never below the embarrassingly-parallel floor W ∈ Θ(p)
    assert cm.isoefficiency_matmul_25d(p, c_max) >= p * (1 - 1e-9)


def test_summa_cost_rectangular():
    """Rectangular grids: p is q_x·q_y and panel maths stays consistent."""
    s = cm.summa_matmul_cost(1024, 2, 4)
    c = cm.cannon_matmul_cost(1024, 2, 4)
    assert s["p"] == c["p"] == 8
    assert s["compute_s"] == pytest.approx(c["compute_s"])
    assert s["total_s"] > 0 and c["total_s"] > 0


# ---------------------------------------------------------------------------
# Serving-path costs (decode_step_cost / prefill_cost)
# ---------------------------------------------------------------------------
def test_decode_step_cost_batch_amortizes_memory_bound():
    """Decode streams the parameters once per step regardless of batch, so
    while memory-bound the aggregate tok/s climbs near-linearly with batch,
    and per-step memory time is flat until KV traffic matters."""
    n_params = 3e9
    c1 = cm.decode_step_cost(n_params, 1)
    c64 = cm.decode_step_cost(n_params, 64)
    assert c1["dominant"] == c64["dominant"] == "memory_s"
    assert c64["memory_s"] == pytest.approx(c1["memory_s"])
    assert c64["tok_s"] == pytest.approx(64 * c1["tok_s"])
    # a huge batch eventually crosses to compute-bound
    big = cm.decode_step_cost(n_params, 1 << 20)
    assert big["dominant"] == "compute_s"
    assert big["tok_s"] < (1 << 20) * c1["tok_s"]


def test_decode_step_cost_kv_and_overhead_terms():
    n_params = 3e9
    base = cm.decode_step_cost(n_params, 8)
    kv = cm.decode_step_cost(n_params, 8, kv_bytes=1e9)
    assert kv["memory_s"] > base["memory_s"]
    assert kv["tok_s"] < base["tok_s"]
    slow = cm.decode_step_cost(n_params, 8, overhead_s=1.0)
    assert slow["total_s"] == pytest.approx(base["total_s"] + 1.0)


def test_prefill_cost_compute_bound_beats_decode_loop():
    """Real prompts are compute-bound in one fused pass; the same tokens as
    a decode-step loop pay the parameter stream per token instead."""
    n_params, prompt = 3e9, 2048
    pre = cm.prefill_cost(n_params, prompt)
    assert pre["dominant"] == "compute_s"
    loop = prompt * cm.decode_step_cost(n_params, 1)["total_s"]
    assert pre["total_s"] < loop / 10
    # short prompts degenerate to the memory-bound decode regime
    assert cm.prefill_cost(n_params, 1)["dominant"] == "memory_s"
