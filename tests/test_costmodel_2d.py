"""Unit tests for the scan / reduce-scatter / 2D-matmul cost-model additions.

Plain pytest (no hypothesis dependency) so these always run; the
hypothesis-widened versions live in test_properties.py.
"""
import math

import pytest

from repro.core import costmodel as cm

PS = [2, 4, 8, 16, 64, 256]


@pytest.mark.parametrize("p", PS[:-1])
def test_t_scan_monotone_in_p(p):
    for m in (1, 1024, 10**9):
        assert cm.t_scan(m, 2 * p) >= cm.t_scan(m, p) - 1e-15


@pytest.mark.parametrize("p", PS[:-1])
def test_t_reduce_scatter_monotone_in_p(p):
    for m in (1, 1024, 10**9):
        assert cm.t_reduce_scatter(m, 2 * p) >= cm.t_reduce_scatter(m, p) - 1e-15
        assert cm.t_reduce_scatter_ring(m, 2 * p) >= \
            cm.t_reduce_scatter_ring(m, p) - 1e-15


@pytest.mark.parametrize("p", PS[:-1])
def test_isoefficiency_summa_monotone_in_p(p):
    assert cm.isoefficiency_matmul_summa(2 * p) > cm.isoefficiency_matmul_summa(p)
    assert cm.isoefficiency_matmul_cannon(2 * p) > cm.isoefficiency_matmul_cannon(p)


@pytest.mark.parametrize("p", [64, 256, 1024, 4096])
def test_isoefficiency_2d_orderings(p):
    """Scalability ladder at scale: DNS (Θ(p log p)) ≤ Cannon (Θ(p^1.5)) ≤
    SUMMA (Θ(p^1.5 log p)), and Cannon ≤ generic (Θ(p^5/3)).  SUMMA vs
    generic flips only at astronomically large p (log p vs p^{1/6}), so it
    is not asserted here."""
    assert cm.isoefficiency_matmul_grid(p) <= cm.isoefficiency_matmul_cannon(p)
    assert cm.isoefficiency_matmul_cannon(p) <= cm.isoefficiency_matmul_summa(p)
    assert cm.isoefficiency_matmul_cannon(p) <= cm.isoefficiency_matmul_generic(p)


def test_scan_cost_shape():
    """t_scan is the reduce cost with the per-round combine included, and is
    latency-exact for powers of two: ceil(log2 p) rounds."""
    assert cm.t_scan(0, 8, cm.ICI) == 3 * cm.ICI.t_s
    assert cm.t_scan(100, 1) == 0.0
    assert cm.t_scan(100, 8, t_lambda=1e-6) > cm.t_scan(100, 8)


def test_reduce_scatter_vs_all_reduce():
    """reduce-scatter is the cheap half of an all-reduce: ≤ t_all_reduce for
    every size/grid."""
    for p in PS:
        for m in (64, 2**20, 10**9):
            assert cm.t_reduce_scatter(m, p) <= cm.t_all_reduce(m, p) + 1e-15


@pytest.mark.parametrize("n,q", [(1024, 2), (4096, 4), (40000, 8)])
def test_summa_cannon_cost_structure(n, q):
    s = cm.summa_matmul_cost(n, q)
    c = cm.cannon_matmul_cost(n, q)
    d = cm.dns_matmul_cost(n, q)
    # all variants do the same useful work and report coherent totals
    assert s["compute_s"] == pytest.approx(c["compute_s"])
    assert s["total_s"] >= s["compute_s"] and c["total_s"] >= c["compute_s"]
    assert s["serial_s"] == pytest.approx(c["serial_s"]) == pytest.approx(d["serial_s"])
    # Cannon's nearest-neighbour traffic never exceeds SUMMA's broadcasts
    assert c["shift_s"] <= s["broadcast_s"] * (1 + 1e-9)
    # 2D memory: no replication — q² processes hold 3n² elements total
    assert s["mem_elts_per_proc"] * q * q == 3 * n * n


def test_summa_cost_rectangular():
    """Rectangular grids: p is q_x·q_y and panel maths stays consistent."""
    s = cm.summa_matmul_cost(1024, 2, 4)
    c = cm.cannon_matmul_cost(1024, 2, 4)
    assert s["p"] == c["p"] == 8
    assert s["compute_s"] == pytest.approx(c["compute_s"])
    assert s["total_s"] > 0 and c["total_s"] > 0
