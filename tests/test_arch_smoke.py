"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + one decode step on CPU; shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ParallelConfig, TrainConfig
from repro.launch.train import reduced
from repro.models import transformer as T
from repro.models import encdec as E
from repro.parallel import steps as S

PCFG = ParallelConfig(remat="none", fsdp_params=False)
TCFG = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10, z_loss=0.0)

# the biggest hybrid archs compile for tens of seconds on CPU — slow tier
_HEAVY = {"zamba2-1.2b", "xlstm-1.3b"}
_HEAVY_FWD = _HEAVY | {"whisper-base"}  # decode stays fast-tier (enc-dec coverage)


def _params(heavy):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in configs.ARCHS]


FORWARD_PARAMS = _params(_HEAVY_FWD)
ARCH_PARAMS = _params(_HEAVY)


@pytest.fixture(scope="session")
def arch_state():
    """Per-arch reduced config + initialized train state, shared by every
    test in the session (init + first trace dominate these smoke tests)."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(configs.get(arch))
            cache[arch] = (cfg, S.init_train_state(jax.random.PRNGKey(0), cfg, PCFG))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", FORWARD_PARAMS)
def test_arch_forward_and_train_step(arch, arch_state):
    cfg, state = arch_state(arch)
    rng = jax.random.PRNGKey(0)
    b, s = 2, 64
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(rng, (b, 32, cfg.d_model))

    # forward
    if cfg.enc_dec:
        logits, aux = E.forward(state["params"], batch["frames"], batch["tokens"], cfg)
    else:
        logits, aux = T.forward(state["params"], batch["tokens"], cfg)
    assert logits.shape == (b, s, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # one train step: loss finite and grads applied
    step = jax.jit(S.make_train_step(cfg, PCFG, TCFG, None))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    changed = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert jax.tree.reduce(max, changed) > 0, "params did not change"


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_decode_step(arch, arch_state):
    cfg, state = arch_state(arch)
    params = state["params"]
    rng = jax.random.PRNGKey(0)
    b, max_len = 2, 32
    if cfg.enc_dec:
        enc = E.encode(params, jax.random.normal(rng, (b, 16, cfg.d_model)), cfg)
        cache = E.init_cache(cfg, b, max_len)
        tok = jax.random.randint(rng, (b,), 0, cfg.vocab)
        logit, cache = E.decode_step(params, tok, cache, jnp.int32(0), enc, cfg)
    else:
        cache = T.init_cache(cfg, b, max_len)
        tok = jax.random.randint(rng, (b,), 0, cfg.vocab)
        logit, cache = T.decode_step(params, tok, cache, jnp.int32(0), cfg)
    assert logit.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logit, np.float32)))


def test_decode_matches_forward_dense():
    """Greedy decode over a prompt reproduces the forward logits (llama-style
    reduced config): the KV-cache path is consistent with teacher forcing."""
    cfg = reduced(configs.get("llama3.2-3b")).replace(dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = T.init(rng, cfg)
    b, s = 1, 8
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    full_logits, _ = T.forward(params, toks, cfg)

    cache = T.init_cache(cfg, b, s, dtype=jnp.float32)
    for i in range(s):
        step_logit, cache = T.decode_step(params, toks[:, i], cache,
                                          jnp.int32(i), cfg)
        np.testing.assert_allclose(np.asarray(step_logit),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-2, atol=2e-2)
