"""Paper §5: parallel Floyd-Warshall (Algorithm 3) + the blocked min-plus
variant with the Pallas kernel.

Run:  PYTHONPATH=src python examples/floyd_warshall.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core import (floyd_warshall, blocked_floyd_warshall,
                        floyd_warshall_reference, make_grid_mesh)
from repro.kernels.ops import minplus

n = 64
rng = np.random.RandomState(0)
W = rng.rand(n, n).astype(np.float32) * 10
W[np.diag_indices(n)] = 0
D = jnp.array(W)

mesh = make_grid_mesh((2, 2), ("x", "y"))
ref = floyd_warshall_reference(D)

got = floyd_warshall(D, mesh)                       # paper Algorithm 3
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)
print(f"Floyd-Warshall Alg3 (n={n}, 2x2 grid): correct")

got2 = blocked_floyd_warshall(D, mesh)              # blocked (beyond paper)
np.testing.assert_allclose(np.asarray(got2), np.asarray(ref), rtol=1e-5)
print("blocked 3-phase FW: correct")

got3 = blocked_floyd_warshall(D, mesh, minplus=partial(minplus, interpret=True,
                                                       bm=32, bn=32, bk=32))
np.testing.assert_allclose(np.asarray(got3), np.asarray(ref), rtol=1e-4)
print("blocked FW + Pallas (min,+) kernel: correct")
print(f"shortest path 0->{n-1}: {float(got[0, n-1]):.3f}")
