"""Serve a reduced LM with batched requests (production serving driver).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch chatglm3-6b]
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.argv = [sys.argv[0], "--requests", "4", "--prompt-len", "16", "--gen", "16"] \
    + sys.argv[1:]
from repro.launch.serve import main

if __name__ == "__main__":
    main()
