"""Paper §4: DNS matrix-matrix multiplication with the Grid3D abstraction
(Algorithm 2) vs the generic for-loop version (Algorithm 1).

Run:  PYTHONPATH=src python examples/dns_matmul.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp, numpy as np
from repro.core import dns_matmul, dns_matmul_pallas, generic_matmul, make_grid_mesh
from repro.core.costmodel import dns_matmul_cost

n = 512
A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)

mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))   # q^3 = 8 processes
C = jax.jit(lambda a, b: dns_matmul(a, b, mesh3))(A, B)
np.testing.assert_allclose(np.asarray(C), np.asarray(A @ B), rtol=1e-3, atol=1e-3)
print(f"Grid3D DNS matmul ({n}x{n} on 2x2x2): correct")

# the same algorithm with the Pallas MXU kernel as the local multiply
C2 = dns_matmul_pallas(A, B, mesh3)
np.testing.assert_allclose(np.asarray(C2), np.asarray(A @ B), rtol=1e-2, atol=1e-2)
print("DNS + Pallas local-multiply kernel: correct")

# Algorithm 1 (generic, sequential ∀-emulation) — the paper's scalability foil
mesh1 = make_grid_mesh((8,), ("z",))
t0 = time.perf_counter(); jax.block_until_ready(
    jax.jit(lambda a, b: generic_matmul(a, b, mesh1, "z"))(A, B))
t_gen = time.perf_counter() - t0
t0 = time.perf_counter(); jax.block_until_ready(
    jax.jit(lambda a, b: dns_matmul(a, b, mesh3))(A, B))
t_dns = time.perf_counter() - t0
print(f"generic(Alg1)={t_gen*1e3:.0f}ms  grid(Alg2)={t_dns*1e3:.0f}ms  "
      f"(isoefficiency Θ(p^5/3) vs Θ(p log p))")

# predicted at TPU scale (the paper's Carver experiment, forecast for v5e)
pred = dns_matmul_cost(40000, 8, bytes_per_elt=2)
print(f"cost-model forecast n=40000, p=512 v5e chips: "
      f"E={pred['serial_s']/(512*pred['total_s']):.2f}")
