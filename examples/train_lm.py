"""End-to-end driver: train a reduced LM for a few hundred steps with
fault-tolerant checkpointing (delegates to the production launcher).

Run:  PYTHONPATH=src python examples/train_lm.py [--arch mixtral-8x22b]
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.argv = [sys.argv[0], "--steps", "200", "--batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_example_ckpt"] + sys.argv[1:]
from repro.launch.train import main

if __name__ == "__main__":
    main()
