"""FooPar-in-JAX quickstart — the paper's §3.2 SPMD example.

    def ones(i: Int) = i.toBinaryString.count(_ == '1')
    val counts = (0 until worldSize) mapD ones

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import DSeq, spmd, make_grid_mesh

mesh = make_grid_mesh((8,), ("x",))

# the distributed sequence 0..worldSize-1; element i lives on process i
seq = jnp.arange(8, dtype=jnp.uint32)


def program(local):
    s = DSeq(local[0], "x")
    # mapD: every process counts the 1-bits of ITS element (popcount)
    counts = s.mapD(lambda v: jax.lax.population_count(v))
    # chain group ops: total ones via reduceD (+), then broadcast of element 3
    total = counts.reduceD("sum")
    third = counts.apply(3)
    return counts.local[None], total, third


counts, total, third = spmd(program, mesh, in_specs=P("x"),
                            out_specs=(P("x"), P(), P()))(seq)
print("per-process popcounts:", counts.tolist())       # [0,1,1,2,1,2,2,3]
print("reduceD('+')        :", int(total))             # 12
print("apply(3) broadcast  :", int(third))             # 2
assert counts.tolist() == [0, 1, 1, 2, 1, 2, 2, 3] and int(total) == 12
print("OK — deadlock-free by construction: no process ever sent a message.")
