"""2D parallel matmul: SUMMA and Cannon on a Grid2D, vs the 3D DNS variant.

SUMMA broadcasts k-panels along grid rows/columns (van de Geijn & Watts);
Cannon skews both operands once, then only nearest-neighbour ring shifts.
Both hold Θ(n²/p) per process — no DNS-style operand replication — at the
price of a Θ(p^{3/2}) isoefficiency instead of DNS's Θ(p log p).

Run:  PYTHONPATH=src python examples/summa_matmul.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp, numpy as np
from repro.core import (cannon_matmul, cannon_matmul_25d, cannon_matmul_pallas,
                        dns_matmul, make_grid_mesh, summa_matmul,
                        summa_matmul_pallas, summa_matmul_pipelined)
from repro.core.costmodel import (cannon_25d_cost, cannon_matmul_cost,
                                  summa_matmul_cost, summa_pipelined_cost)
from repro.launch.roofline import matmul_scenarios_table

n = 512
A = jnp.array(np.random.RandomState(0).randn(n, n), jnp.float32)
B = jnp.array(np.random.RandomState(1).randn(n, n), jnp.float32)
want = np.asarray(A @ B)

# square 2x2 grid (4 of the 8 devices) and rectangular 2x4 grid (all 8)
mesh_sq = jax.make_mesh((2, 2), ("x", "y"), devices=jax.devices()[:4])
mesh_rc = make_grid_mesh((2, 4), ("x", "y"))

for name, mesh in (("2x2", mesh_sq), ("2x4", mesh_rc)):
    C = jax.jit(lambda a, b: summa_matmul(a, b, mesh))(A, B)
    np.testing.assert_allclose(np.asarray(C), want, rtol=1e-3, atol=1e-3)
    C = jax.jit(lambda a, b: cannon_matmul(a, b, mesh))(A, B)
    np.testing.assert_allclose(np.asarray(C), want, rtol=1e-3, atol=1e-3)
    print(f"SUMMA + Cannon on {name} grid: correct")

# the same algorithms with the Pallas MXU kernel as the local multiply
np.testing.assert_allclose(np.asarray(summa_matmul_pallas(A, B, mesh_sq)),
                           want, rtol=1e-2, atol=1e-2)
np.testing.assert_allclose(np.asarray(cannon_matmul_pallas(A, B, mesh_sq)),
                           want, rtol=1e-2, atol=1e-2)
print("SUMMA + Cannon with Pallas local-multiply kernel: correct")

# the overlapped/replicated tier: pipelined SUMMA (ring transfers hidden
# behind compute) and 2.5D Cannon (2-fold replication on the 2x2x2 mesh)
mesh3 = make_grid_mesh((2, 2, 2), ("x", "y", "z"))
C = jax.jit(lambda a, b: summa_matmul_pipelined(a, b, mesh_rc))(A, B)
np.testing.assert_allclose(np.asarray(C), want, rtol=1e-3, atol=1e-3)
C = jax.jit(lambda a, b: cannon_matmul_25d(a, b, mesh3))(A, B)
np.testing.assert_allclose(np.asarray(C), want, rtol=1e-3, atol=1e-3)
print("pipelined SUMMA + 2.5D Cannon: correct")

# measured: the full five-variant scenario space on the same 8 chips
for name, fn in (("summa", lambda a, b: summa_matmul(a, b, mesh_rc)),
                 ("summa-pipe", lambda a, b: summa_matmul_pipelined(a, b, mesh_rc)),
                 ("cannon", lambda a, b: cannon_matmul(a, b, mesh_rc)),
                 ("cannon-2.5d", lambda a, b: cannon_matmul_25d(a, b, mesh3)),
                 ("dns", lambda a, b: dns_matmul(a, b, mesh3))):
    jitted = jax.jit(fn)
    jax.block_until_ready(jitted(A, B))
    t0 = time.perf_counter()
    jax.block_until_ready(jitted(A, B))
    print(f"{name:11s} {1e3 * (time.perf_counter() - t0):7.1f} ms")

# forecast at TPU scale: the full scenario table from the Table-1 cost model
print("\ncost-model forecast, n=40000 on 64 v5e chips:")
print(matmul_scenarios_table(40000, 64))
pred_s = summa_matmul_cost(40000, 8, bytes_per_elt=2)
pred_p = summa_pipelined_cost(40000, 2, 32, bytes_per_elt=2)
pred_c = cannon_matmul_cost(40000, 8, bytes_per_elt=2)
pred_25 = cannon_25d_cost(40000, 4, 4, bytes_per_elt=2)
print(f"\nSUMMA  E={pred_s['serial_s'] / (64 * pred_s['total_s']):.2f}   "
      f"SUMMA-pipe(2x32) E={pred_p['serial_s'] / (64 * pred_p['total_s']):.2f}   "
      f"Cannon E={pred_c['serial_s'] / (64 * pred_c['total_s']):.2f}   "
      f"Cannon-2.5D(4²x4) E={pred_25['serial_s'] / (64 * pred_25['total_s']):.2f}")
